"""Forward-only flash attention (Pallas TPU), GQA + causal.

ZO fine-tuning needs no backward pass, so the *inference* kernel is the
training kernel -- no stored softmax statistics, no recompute policy.
Online-softmax over K/V tiles keeps the (bq, bk) score tile in VMEM; the
(S, T) score matrix never exists in HBM. For qwen3-4b train_4k the
XLA-fallback chunked attention writes+reads ~1.2 TB/chip/step of f32
scores (the dominant HBM term, EXPERIMENTS.md Sec Perf); with this kernel
that traffic is exactly zero.

Layout: q (B, S, KV, G, hd); k/v (B, T, KV, hd). Grid (B*KV*G, nq, nk),
k-tiles innermost, accumulators (acc, m, l) in VMEM scratch across the
k-loop. Causal tiles fully above the diagonal are masked out (the
pl.when guard skips their dot on TPU; interpret mode computes and masks).

Block sizes default to (128, 128) -- MXU-aligned for hd in {64,112,128,
256} via full-head-dim tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq, bk, n_k, causal, scale):
    kk = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = (not causal) or (qi * bq + bq - 1 >= kk * bk)

    @pl.when(live)
    def _():
        q = q_ref[0, :, 0, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kk == n_k - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blocks",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, blocks=(128, 128),
                    interpret: bool = False):
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) -> (B, S, H, hd)."""
    from repro.kernels.flash_decode import check_head_dim
    b, s, h, hd = q.shape
    check_head_dim(hd, interpret=interpret, kernel="flash_attention")
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)

    def pick(dim, want):
        bb = min(want, dim)
        while dim % bb:
            bb -= 1
        return bb

    bq, bk = pick(s, blocks[0]), pick(t, blocks[1])
    grid = (b * kvh * g, s // bq, t // bk)

    def qmap(p, qi, kk):
        return (p // (kvh * g), qi, (p // g) % kvh, p % g, 0)

    def kmap(p, qi, kk):
        return (p // (kvh * g), kk, (p // g) % kvh, 0)

    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=grid[2],
                             causal=causal,
                             scale=1.0 / float(hd) ** 0.5)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, hd), qmap),
            pl.BlockSpec((1, bk, 1, hd), kmap),
            pl.BlockSpec((1, bk, 1, hd), kmap),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, 1, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((b, s, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, s, h, hd)
