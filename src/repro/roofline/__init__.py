from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.analysis import roofline_terms

__all__ = ["collective_bytes", "parse_collectives", "roofline_terms"]
