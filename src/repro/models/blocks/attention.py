"""Self-attention block: GQA/MQA attention over a (B, S_max, KV, hd)
KV cache. Full-sequence apply wraps :func:`repro.models.layers.attn_apply`
(fused-ZO aware); prefill writes cache positions [0, P) in one
``dynamic_update_slice``; decode updates position ``pos`` (scalar, or a
per-slot (B,) vector for continuous batching).

Paged mode: the K/V leaves can instead live in a shared page pool
(``k_pages``/``v_pages``: (n_pages, page_size, KV, hd) per layer) with a
per-slot page table threaded through ``rc.pages``. Decode then writes
the new token into its slot's page and attends only over live pages via
the flash-decoding kernel (TPU) or its gather reference -- the dense
path's full-S_max read of dead cache disappears. Physical page 0 is the
pool's trash page: masked-out slots (rc.write_mask) and unallocated page
table entries point there, so scatters need no gather-merge and gathers
need no index clamping."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops import (paged_decode_attn, paged_prefill_attn,
                               paged_verify_attn)
from repro.models import layers as L
from repro.models.blocks.base import BlockType, register_block


def _apply(cfg, p, x, rc, ctx=None, causal=None):
    y = L.attn_apply(cfg, p, x, positions=rc.positions, kv_mask=rc.kv_mask,
                     causal=causal, ctx=ctx)
    return y, jnp.float32(0.0)


def _state_spec(cfg, bsz, max_len, dtype):
    shape = (bsz, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k": (shape, dtype), "v": (shape, dtype)}


def _paged_state_spec(cfg, dtype):
    shape = (cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k_pages": (shape, dtype), "v_pages": (shape, dtype)}


def _decode_paged(cfg, p, state, x, rc):
    """One-token attention against the shared page pool. ``rc.pos`` is
    the (B,) per-slot position, ``rc.pages`` the (B, n_live) physical
    page table slice covering every live page."""
    ck, cv = state["k_pages"], state["v_pages"]     # (NP, ps, KV, hd)
    b = x.shape[0]
    ps = ck.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(rc.pos), (b,))
    q, k, v = L.attn_project_qkv(cfg, p, x)       # (B,1,H,hd),(B,1,KV,hd)
    if cfg.pos == "rope":
        cs = L.rope_cos_sin(pos[:, None], cfg.resolved_head_dim,
                            cfg.rope_pct, cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    phys = jnp.take_along_axis(rc.pages, (pos // ps)[:, None], axis=1)[:, 0]
    if rc.write_mask is not None:
        phys = jnp.where(rc.write_mask, phys, 0)    # masked slots -> trash
    off = pos % ps
    ck = ck.at[phys, off].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[phys, off].set(v[:, 0].astype(cv.dtype))
    out = paged_decode_attn(q[:, 0], ck, cv, rc.pages, pos)
    return (L.dense(p["wo"], out.reshape(b, 1, -1)),
            {"k_pages": ck, "v_pages": cv})


def _decode_step(cfg, p, state, x, rc, ctx=None, causal=None):
    """One-token attention against the cache layer. ``rc.pos`` is a
    scalar (the whole batch decodes at one position) or a (B,) vector
    (continuous batching: each slot at its own position)."""
    if "k_pages" in state:
        return _decode_paged(cfg, p, state, x, rc)
    ck, cv = state["k"], state["v"]
    b = x.shape[0]
    pos = jnp.asarray(rc.pos)
    q, k, v = L.attn_project_qkv(cfg, p, x)       # (B,1,H,hd),(B,1,KV,hd)
    if cfg.pos == "rope":
        pos_b = pos[:, None] if pos.ndim else jnp.full((b, 1), pos)
        cs = L.rope_cos_sin(pos_b, cfg.resolved_head_dim,
                            cfg.rope_pct, cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    if pos.ndim:
        def upd(c, u, p_):
            return jax.lax.dynamic_update_slice(c, u, (p_, 0, 0))
        ck = jax.vmap(upd)(ck, k.astype(ck.dtype), pos)
        cv = jax.vmap(upd)(cv, v.astype(cv.dtype), pos)
        valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
    else:
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        valid = (jnp.arange(ck.shape[1]) <= pos)[None, :]
    out = L.attention(q, ck, cv, causal=False, kv_mask=valid, chunk=0)
    return L.dense(p["wo"], out.reshape(b, 1, -1)), {"k": ck, "v": cv}


def _window_paged(cfg, p, state, x, rc, attn, what):
    """Shared scatter-then-read over the page pool for every multi-token
    paged entry (speculative verify, chunked prefill): the W tokens' own
    K/V is written through the page table *first* (masked slots/offsets
    scatter into the trash page), then the attention read -- page gather
    plus causal-within-window masking -- sees exactly what a sequential
    decode of those tokens would have cached."""
    if "k_pages" not in state:
        raise ValueError(f"{what} needs a paged KV cache "
                         "(attention state has no k_pages pool)")
    ck, cv = state["k_pages"], state["v_pages"]     # (NP, ps, KV, hd)
    b, w = x.shape[:2]
    ps = ck.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(rc.pos), (b,))
    q, k, v = L.attn_project_qkv(cfg, p, x)       # (B,W,H,hd),(B,W,KV,hd)
    posw = pos[:, None] + jnp.arange(w)[None, :]  # (B, W) logical positions
    if cfg.pos == "rope":
        cs = L.rope_cos_sin(posw, cfg.resolved_head_dim,
                            cfg.rope_pct, cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    phys = jnp.take_along_axis(rc.pages, posw // ps, axis=1)
    if rc.write_mask is not None:
        wm = jnp.asarray(rc.write_mask, bool)
        if wm.ndim == 1:
            wm = wm[:, None]
        phys = jnp.where(wm, phys, 0)               # masked -> trash
    off = posw % ps
    ck = ck.at[phys, off].set(k.astype(ck.dtype))
    cv = cv.at[phys, off].set(v.astype(cv.dtype))
    out = attn(q, ck, cv, rc.pages, pos)
    return (L.dense(p["wo"], out.reshape(b, w, -1)),
            {"k_pages": ck, "v_pages": cv})


def _verify_paged(cfg, p, state, x, rc, ctx=None, causal=None):
    """Speculative-verify window: score W candidate tokens per slot at
    positions ``rc.pos .. rc.pos + W - 1`` against the page pool. The
    verifier's own K/V for the window is scattered into the slot's pages
    first (overwriting whatever the draft wrote there), so verification
    is exact and speculation costs zero extra KV HBM. ``rc.write_mask``
    is (B, W): offsets past a slot's live window (and whole masked-out
    slots) scatter into the trash page."""
    return _window_paged(cfg, p, state, x, rc, paged_verify_attn,
                         "verify window")


def _prefill_paged(cfg, p, state, x, rc, ctx=None, causal=None):
    """Chunked prefill: write a C-token prompt chunk's K/V straight into
    the slot's reserved pages and attend over all prior chunks plus
    causally within this one -- the flash-prefill kernel sweep. Same
    scatter-then-read contract as verify; only the read kernel differs
    (one page sweep per (slot, kv head) with the whole chunk resident,
    not one per window offset)."""
    return _window_paged(cfg, p, state, x, rc, paged_prefill_attn,
                         "chunked prefill")


def _prefill(cfg, p, state, x, rc, ctx=None, causal=None):
    """Full-prompt attention that also writes positions [0, S) of the
    cache layer -- causal masking keeps every prompt token's view
    identical to the per-token decode loop's."""
    ck, cv = state["k"], state["v"]
    b, s, _ = x.shape
    q, k, v = L.attn_project_qkv(cfg, p, x)
    if cfg.pos == "rope":
        cs = L.rope_cos_sin(rc.positions, cfg.resolved_head_dim,
                            cfg.rope_pct, cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
    out = L.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    return L.dense(p["wo"], out.reshape(b, s, -1)), {"k": ck, "v": cv}


ATTENTION = register_block(BlockType(
    name="attention", init=L.attn_init, apply=_apply,
    state_spec=_state_spec, prefill=_prefill, decode_step=_decode_step,
    paged_state_spec=_paged_state_spec, verify=_verify_paged,
    prefill_paged=_prefill_paged))
