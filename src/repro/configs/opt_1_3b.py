"""OPT-1.3B (paper's own model, Sec 4.1: fine-tuned on SuperGLUE)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="opt-1.3b", family="dense", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=50272,
        act="relu", norm="layernorm", pos="learned", max_seq=2048)
