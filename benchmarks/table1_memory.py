"""Paper Table 1: memory for MeZO vs Adam fine-tuning, x batch size.

Three measurements, mirroring the paper's phone-RSS numbers on this
container/TPU target:

  (a) live RSS around train steps on reduced RoBERTa, batch 8 vs 64
      (the paper's exact axis: MeZO flat in batch, Adam grows),
  (b) analytic state bytes at FULL RoBERTa-large / OPT-1.3B scale
      (params/grads/moments/activations model),
  (c) per-device compiled bytes from dry-run JSONs when present,
  (d) the ``fused_families`` arm: compiled peak live-buffer bytes of the
      ZO loss for the families the block-registry runtime moved off the
      transient-materialize fallback (hybrid, rwkv6, encdec) -- fused
      in-place perturbation vs. an explicit theta+eps*z copy,
  (e) the ``quant`` arm: resident weight bytes of the int8 quantized
      base (per-channel scales included) vs the f32 fused baseline for
      a dense and a non-dense family, plus the atol=0 check that the
      quantized fused loss equals the materialized dequant(Wq)+eps*z
      loss -- the acceptance numbers of the quantized-base runtime.
"""

from __future__ import annotations

import gc
import json
import os
import resource
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig, PerturbCtx, mezo_step
from repro.data.synthetic import lm_batch_at, synthetic_lm_corpus
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, grad_train_step
from repro.optim.quant import quantize_tree, quantized_bytes
from repro.roofline.analysis import total_params


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _steps(cfg, optimizer: str, batch_size: int, n: int = 3) -> float:
    """Peak RSS (MB) after n train steps at the given batch size."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = synthetic_lm_corpus(batch_size * 40 * 33, cfg.vocab, 0)
    state = adam_init(params) if optimizer == "adam" else None
    mcfg = MezoConfig(eps=1e-3, lr=1e-5)
    for t in range(n):
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch_at(t, batch_size, 32, cfg.vocab, stream).items()}
        if optimizer == "adam":
            params, state, _ = grad_train_step(model.loss, params, batch,
                                               state, AdamConfig())
        else:
            params, _ = mezo_step(model.loss, params, batch, jnp.uint32(t),
                                  mcfg)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    return _rss_mb()


def analytic_state_gb(arch: str, batch: int, seq: int, optimizer: str):
    """Full-scale state-memory model (the paper's mechanism, Sec 3.3)."""
    cfg = get_config(arch)
    n = total_params(cfg)
    bp = 4 if cfg.dtype == "float32" else 2
    act_per_layer = batch * seq * cfg.d_model * 4 * 6  # rough backprop saves
    if optimizer == "mezo":
        # params + ONE layer's transient activations (forward only)
        return (n * bp + batch * seq * cfg.d_model * 4 * 2) / 1e9
    # adam: params + grads + 2 fp32 moments + saved activations (all layers)
    layers = cfg.n_layers if cfg.family != "encdec" else \
        cfg.enc_layers + cfg.dec_layers
    return (n * (bp + bp + 8) + act_per_layer * layers) / 1e9


# deep enough that the layer scan is a real loop: with a length-1 scan
# XLA inlines the body and fuses the transient perturbed copies into
# their consumers, hiding exactly the cost this arm measures
FUSED_FAMILY_ARCHS = {
    "jamba-v0.1-52b": dict(n_layers=8),          # 2 super-blocks
    "rwkv6-7b": dict(n_layers=4),
    "whisper-base": dict(enc_layers=2, dec_layers=2),
}


def fused_families(rows, table):
    """Peak live-buffer bytes of the ZO loss, fused vs materialize.

    Two views per family, both committed to the JSON:
      * measured: ``live = argument + temp`` from the compiled memory
        analysis -- the materialize arm's temp holds the transient
        theta+eps*z copies of every scan-stacked leaf, the fused arm's
        does not (z is regenerated at each use site);
      * weight-resident: params vs params + perturbable-leaf copy (the
        paper's Sec 3.3 accounting) -- the fused path fine-tunes at
        inference weight memory, the materialize path at ~2x.
    """
    for arch, depth in FUSED_FAMILY_ARCHS.items():
        cfg = get_config(arch).reduced(**depth)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch_at(0, 2, 32, cfg.vocab,
                             synthetic_lm_corpus(2 * 40 * 33, cfg.vocab,
                                                 0)).items()}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1), (2, cfg.enc_len, cfg.d_model))
        ctx = PerturbCtx(seed=jnp.uint32(7), coeff=jnp.float32(1e-3))
        param_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(params))

        def loss_fused(p, b):
            return model.loss(p, b, perturb=ctx)

        def loss_materialize(p, b):
            return model.loss(ctx.materialize(p), b)

        live = {}
        for name, fn in (("fused", loss_fused),
                         ("materialize", loss_materialize)):
            ma = jax.jit(fn).lower(params, batch).compile().memory_analysis()
            live[name] = int(ma.argument_size_in_bytes
                             + ma.temp_size_in_bytes)
            table[f"fused_families/{arch}/{name}"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "live_peak_bytes": live[name],
            }
        ratio = live["fused"] / max(live["materialize"], 1)
        # weight-resident accounting: the materialize arm's extra temp is
        # the transient perturbed parameter copy, so weight bytes are
        # params (fused) vs params + copy (materialize)
        copy_bytes = max(
            table[f"fused_families/{arch}/materialize"]["temp_bytes"]
            - table[f"fused_families/{arch}/fused"]["temp_bytes"], 0)
        wratio = param_bytes / max(param_bytes + copy_bytes, 1)
        table[f"fused_families/{arch}/param_bytes"] = param_bytes
        table[f"fused_families/{arch}/fused_over_materialize"] = ratio
        table[f"fused_families/{arch}/weight_bytes"] = {
            "fused": param_bytes, "materialize": param_bytes + copy_bytes,
            "fused_over_materialize": wratio}
        rows.append((f"table1/fused_families/{arch}", 0.0,
                     f"fused_live={live['fused']};"
                     f"materialize_live={live['materialize']};"
                     f"live_ratio={ratio:.2f};weight_ratio={wratio:.2f}"))


# dense + non-dense coverage for the quantized-base acceptance numbers;
# the other three families are held to the same parity in
# tests/test_runtime_parity.py's quantized arm
QUANT_ARCHS = ("gemma-2b", "rwkv6-7b")


def quant_arm(rows, table):
    """Resident weight bytes: int8 base (+ per-channel f32 scales) vs
    the f32 fused baseline, plus the fused-vs-materialized atol=0 check.

    The fused ZO path already fine-tunes at inference weight memory
    (arm d); this arm shows that memory itself dropping ~4x when the
    base is int8 -- the dequant rides inside the same perturbed-forward
    kernels, so no arm of the step ever holds an f32 weight copy.

    Scope (recorded as ``weight_bytes_int8_training``): the ~4x number
    is the FROZEN base -- serving, eval, and the shared-across-users
    tree. Training with ``--quant int8`` additionally attaches a
    full-shape f32 delta per quantized leaf (the additive side that
    receives the update stream), so the training-time resident weight
    bytes are base + delta (~1.26x of plain f32 training); the win
    during training is that ONE frozen int8 base serves any number of
    concurrent per-user fine-tunes whose marginal state is the delta
    (or, compacted, the few-KB replay log).
    """
    for arch in QUANT_ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams = quantize_tree(params)
        resident, f32_eq = quantized_bytes(qparams)
        train_resident, _ = quantized_bytes(
            quantize_tree(params, with_delta=True))
        ratio = f32_eq / max(resident, 1)

        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch_at(0, 2, 32, cfg.vocab,
                             synthetic_lm_corpus(2 * 40 * 33, cfg.vocab,
                                                 0)).items()}
        ctx = PerturbCtx(seed=jnp.uint32(7), coeff=jnp.float32(1e-3))
        fused = np.asarray(model.loss(qparams, batch, perturb=ctx),
                           np.float32)
        mat = np.asarray(model.loss(ctx.materialize(qparams), batch),
                         np.float32)
        parity_atol0 = bool(fused == mat)

        table[f"quant/{arch}"] = {
            "weight_bytes_f32": int(f32_eq),
            "weight_bytes_int8": int(resident),
            "weight_bytes_int8_training": int(train_resident),
            "f32_over_int8": ratio,
            "fused_loss": float(fused),
            "materialized_loss": float(mat),
            "fused_equals_materialized_atol0": parity_atol0,
        }
        rows.append((f"table1/quant/{arch}", 0.0,
                     f"f32_bytes={f32_eq};int8_bytes={resident};"
                     f"ratio={ratio:.2f};parity_atol0={parity_atol0}"))


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    table = {}

    # (a) live RSS on reduced roberta (paper's axis: batch 8 vs 64)
    cfg = get_config("opt-1.3b").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab=256)
    for opt in ("mezo", "adam"):
        for bs in (8, 64):
            t0 = time.perf_counter()
            rss = _steps(cfg, opt, bs)
            us = (time.perf_counter() - t0) / 3 * 1e6
            rows.append((f"table1/live_rss/{opt}/bs{bs}", us,
                         f"rss_mb={rss:.0f}"))
            table[f"live/{opt}/bs{bs}"] = rss

    # (b) analytic full-scale numbers (paper: roberta 4GB, opt-1.3b 6.5GB)
    for arch, bs in (("roberta-large", 8), ("roberta-large", 64),
                     ("opt-1.3b", 8)):
        for opt in ("mezo", "adam"):
            gb = analytic_state_gb(arch, bs, 128 if "roberta" in arch
                                   else 512, opt)
            rows.append((f"table1/analytic/{arch}/{opt}/bs{bs}", 0.0,
                         f"state_gb={gb:.2f}"))
            table[f"analytic/{arch}/{opt}/bs{bs}"] = gb

    # (c) compiled per-device bytes from dry-run artifacts, if present
    dd = "experiments/dryrun"
    if os.path.isdir(dd):
        for f in sorted(os.listdir(dd)):
            if "train_4k" not in f or not f.endswith(".json"):
                continue
            rec = json.load(open(os.path.join(dd, f)))
            if rec.get("status") != "ok":
                continue
            ma = rec.get("memory_analysis", {})
            arg = ma.get("argument_size_in_bytes")
            tmp = ma.get("temp_size_in_bytes")
            if arg is not None:
                rows.append((f"table1/dryrun/{rec['arch']}/"
                             f"{rec.get('optimizer')}", 0.0,
                             f"arg_gb={arg/1e9:.2f};temp_gb={tmp/1e9:.2f}"))

    # (d) fused-vs-materialize compiled live bytes per newly-fused family
    # (AFTER the RSS arm: compiling six loss programs here first would
    # raise the process ru_maxrss floor that arm (a) reads)
    fused_families(rows, table)

    # (e) int8 quantized base vs f32 fused: resident weight bytes + parity
    quant_arm(rows, table)

    with open(os.path.join(out_dir, "table1_memory.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows
