"""Model configuration covering every assigned architecture family.

One frozen dataclass; family-specific fields are ignored by other
families. Exact assigned values live in repro/configs/<arch>.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | hybrid | ssm | encdec | encoder
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None   # None -> d_model // n_heads
    d_ff: int = 512
    vocab: int = 1024
    max_seq: int = 2048

    act: str = "swiglu"         # swiglu | geglu | gelu | relu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    qk_norm: bool = False
    pos: str = "rope"           # rope | learned | none
    rope_pct: float = 1.0       # chatglm partial rotary = 0.5
    rope_theta: float = 10000.0
    causal: bool = True
    tie_embeddings: bool = False

    # --- MoE (granite, kimi, jamba FFNs) ---
    n_experts: int = 0
    topk: int = 0
    expert_dff: int = 0          # per-expert hidden dim (kimi: 2048)
    n_shared_experts: int = 0    # kimi-style always-on shared expert
    capacity_factor: float = 1.25
    moe_every: int = 1           # MoE replaces dense FFN every k-th layer
    moe_ep: bool = False         # shard_map expert parallelism (perf opt)
    fsdp_params: bool = False    # 2-D expert-weight sharding (model x data)
                                 # -- needed when params/chip > HBM (kimi 1T)

    # --- hybrid (jamba): repeating block of `block_len` sublayers ---
    block_len: int = 8
    attn_index: int = 4          # which sublayer in the block is attention
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- rwkv6 ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    enc_len: int = 1500          # stub frontend: precomputed frame embeds

    # --- vlm (pixtral): stub frontend of precomputed patch embeds ---
    num_patches: int = 0

    # --- classification head (roberta/SST-2) ---
    n_classes: int = 0

    dtype: str = "bfloat16"
    # attention sequence-chunk size for memory-efficient (online-softmax)
    # attention; 0 = always use plain attention
    attn_chunk: int = 1024
    # 'chunked' (pure-XLA scan, used by the CPU dry-run) or 'flash'
    # (Pallas kernel, kernels/flash_attention.py -- TPU deployment;
    # interpret-mode on CPU, so only reduced configs select it in tests)
    attn_impl: str = "chunked"

    # parallelism hints
    pipeline_stages: int = 1     # PP unused for ZO (no backward) -- must be 1
    # TP sizing: small models (whisper-base: d_model=512) waste the 16-way
    # model axis on tiny shards + per-layer ARs; with use_tp=False weights
    # replicate and the model axis joins the batch axes (pure DP)
    use_tp: bool = True

    def __post_init__(self):
        assert self.pipeline_stages == 1, (
            "PP is deliberately unsupported: ZO training has no backward "
            "pass, so pipeline bubbles buy nothing (DESIGN.md Sec 4)")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid only, per assignment)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 2,
            head_dim=16 if self.head_dim else None,
            d_ff=128,
            vocab=128,
            max_seq=64,
            dtype="float32",
            attn_chunk=0,
        )
        if self.n_experts:
            base.update(n_experts=min(self.n_experts, 4),
                        topk=min(self.topk, 2), expert_dff=64)
        if self.family == "hybrid":
            base.update(n_layers=4, block_len=4, attn_index=2,
                        mamba_d_state=4, mamba_expand=2)
        if self.family == "encdec":
            base.update(enc_layers=1, dec_layers=1, enc_len=8)
        if self.num_patches:
            base.update(num_patches=4)
        if self.n_kv_heads == 1:   # keep MQA archs MQA in the smoke test
            base.update(n_kv_heads=1)
        base.update(overrides)
        return dataclasses.replace(self, **base)
