"""Fault-tolerant training loop for ZO (MeZO) and gradient (Adam) arms.

Responsibilities: build model + shardings, resolve the training strategy
from the engine registry, auto-resume (TrainState snapshot + replay log),
per-step straggler masks, metrics, periodic checkpointing. The loop is
deliberately dumb -- all cleverness lives in core/ and checkpoint/ -- so
its failure behavior is auditable: any crash between two ``on_step``
calls loses at most the step in flight.

Strategy resolution: ``TrainerConfig.optimizer`` names a registered
strategy ("mezo", "mezo-parallel", "mezo-fused", "mezo-momentum", ...)
or "adam" for the gradient baseline; setting ``estimator`` / ``update``
composes any pairing from the engine's estimator×update matrix directly
(e.g. estimator="fused", update="momentum").
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import rng as zrng
from repro.core.engine import (TrainState, build_strategy, get_strategy,
                               strategy_names)
from repro.core.mezo import MezoConfig
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim.adam import AdamConfig, adam_init, grad_train_step
from repro.optim.quant import (check_quant_mode, quantize_tree,
                               tree_is_quantized)
from repro.runtime.stragglers import StragglerPolicy

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "mezo"          # registered strategy name | adam
    estimator: Optional[str] = None  # walk | vmapdir | fused (overrides
    update: Optional[str] = None     # sgd | momentum        .. optimizer)
    mezo: MezoConfig = MezoConfig()
    adam: AdamConfig = AdamConfig()
    quant: str = "none"              # base-weight quantization: none | int8
    n_steps: int = 100
    seed: int = 0
    ckpt_dir: Optional[str] = None
    snapshot_every: int = 100
    log_every: int = 10
    straggler_redundancy: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainerConfig,
                 batches: Iterator[Any], mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.strategy = None
        check_quant_mode(train_cfg.quant)
        if train_cfg.quant != "none" and train_cfg.optimizer == "adam":
            raise ValueError(
                "quantized bases require a ZO strategy: the gradient "
                "baseline differentiates through the weights, but an "
                "int8 base is frozen (updates live in the f32 delta, "
                "written by seed replay)")
        if train_cfg.optimizer == "adam":
            if train_cfg.estimator or train_cfg.update:
                raise ValueError(
                    "TrainerConfig.estimator/.update compose ZO strategies "
                    "and cannot be combined with optimizer='adam' (the "
                    "gradient baseline has no estimator×update axes)")
        else:
            if train_cfg.estimator or train_cfg.update:
                self.strategy = build_strategy(
                    train_cfg.estimator or "walk", train_cfg.update or "sgd")
            elif train_cfg.optimizer not in strategy_names():
                raise ValueError(
                    f"unknown TrainerConfig.optimizer "
                    f"{train_cfg.optimizer!r}; registered strategies: "
                    f"{strategy_names() + ['adam']} (or compose any "
                    f"estimator×update pairing via TrainerConfig.estimator"
                    f"/.update)")
            else:
                self.strategy = get_strategy(train_cfg.optimizer)

        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.model = build_model(model_cfg)
        self.batches = batches
        self.mesh = mesh
        self.log = log_fn
        self.losses: list = []
        self._pending: list = []     # device loss scalars awaiting host sync
        self._straggler = (StragglerPolicy(
            train_cfg.mezo.n_directions,
            train_cfg.straggler_redundancy)
            if train_cfg.straggler_redundancy else None)

        self.ckpt = (CheckpointManager(
            train_cfg.ckpt_dir,
            mezo_cfg=(self._mezo_cfg() if self.strategy else None),
            snapshot_every=train_cfg.snapshot_every,
            update_rule=(self.strategy.update if self.strategy else None))
            if train_cfg.ckpt_dir else None)

    # -- setup ------------------------------------------------------------
    def init_params(self) -> PyTree:
        return self.model.init(jax.random.PRNGKey(self.tcfg.seed))

    def _maybe_quantize(self, params: PyTree) -> PyTree:
        """One-shot base quantization (TrainerConfig.quant). Deltas are
        attached so every update rule can write the f32 stream; a tree
        that arrives already quantized passes through."""
        if self.tcfg.quant == "none" or tree_is_quantized(params):
            return params
        return quantize_tree(params, self.tcfg.quant, with_delta=True)

    def _mezo_cfg(self) -> MezoConfig:
        c = self.tcfg.mezo
        if self._straggler:
            c = dataclasses.replace(
                c, n_directions=self._straggler.total)
        return c

    def _init_state(self, params: PyTree, mcfg: MezoConfig) -> TrainState:
        if self.strategy is not None:
            return self.strategy.init_state(params, mcfg)
        return TrainState(params=params, step=jnp.uint32(0),
                          opt=adam_init(params))

    def _sync_losses(self):
        """Host-sync the buffered device scalars (one transfer per batch
        of steps instead of one per step)."""
        if self._pending:
            self.losses.extend(float(x) for x in self._pending)
            self._pending.clear()

    # -- main loop --------------------------------------------------------
    def train(self, params: Optional[PyTree] = None,
              fail_at: Optional[int] = None) -> PyTree:
        """Runs to n_steps with auto-resume. ``fail_at`` raises at that
        step (fault-injection for tests)."""
        start = 0
        mcfg = self._mezo_cfg()
        resume = params is None
        if params is None:
            params = self.init_params()
        params = self._maybe_quantize(params)
        state = self._init_state(params, mcfg)
        if resume and self.ckpt:
            restored, start = self.ckpt.restore(state)
            if restored is not None:
                state = restored
                self.log(f"[trainer] resumed at step {start}")

        t0 = time.perf_counter()
        for step in range(start, self.tcfg.n_steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(self.batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            seed = zrng.fold_seed(jnp.uint32(self.tcfg.seed), step)

            mask = None
            if self.strategy is None:
                p, opt, loss = grad_train_step(
                    self.model.loss, state.params, batch, state.opt,
                    self.tcfg.adam)
                state = TrainState(params=p, step=jnp.uint32(step + 1),
                                   opt=opt)
                aux = None
                self._pending.append(loss)
            else:
                if self._straggler:
                    mask = jnp.asarray(self._straggler.mask())
                state, aux = self.strategy.step(
                    self.model.loss, state, batch, seed, mcfg, mask)
                self._pending.append(aux.loss)

            if self.ckpt:
                self.ckpt.on_step(step, state, aux, direction_mask=mask)
            if step % self.tcfg.log_every == 0:
                self._sync_losses()
                dt = time.perf_counter() - t0
                self.log(f"[trainer] step={step} loss={self.losses[-1]:.4f} "
                         f"({dt:.1f}s)")
        self._sync_losses()
        return state.params


def train_multi_tenant(model_cfg: ModelConfig, jobs, *, n_slots: int = 4,
                       estimator: str = "fused", update: str = "sgd",
                       seed: int = 0, mezo_cfg: Optional[MezoConfig] = None,
                       quant: str = "none", store=None,
                       log_dir: Optional[str] = None,
                       log_fn: Callable[[str], None] = print):
    """One-call multi-tenant path: run ``jobs`` (TrainJob sequence)
    through a batched :class:`repro.train.TrainEngine` over one shared
    base -- each job's trajectory bit-identical to a lone
    :class:`Trainer` with ``seed=derive_user_seed(seed, job.user)``.

    ``quant="int8"`` quantizes the freshly initialized base before the
    store adopts it (ignored when an explicit ``store`` brings its own
    base). Returns ``(engine, results)``: the engine for its stats and
    store, results jid-sorted.
    """
    from repro.serve.adapters import AdapterStore
    from repro.train import TrainEngine

    check_quant_mode(quant)
    if store is None:
        params = build_model(model_cfg).init(jax.random.PRNGKey(seed))
        if quant != "none":
            params = quantize_tree(params, quant, with_delta=True)
        store = AdapterStore(params, mezo_cfg=mezo_cfg or MezoConfig(),
                             update_rule=build_strategy(
                                 estimator, update).update)
    engine = TrainEngine(model_cfg, store, n_slots=n_slots,
                         estimator=estimator, update=update, seed=seed,
                         mezo_cfg=mezo_cfg, log_dir=log_dir)
    for job in jobs:
        engine.submit(job)
    results = engine.run()
    s = engine.stats
    log_fn(f"[fleet] {s.finished} jobs, {s.user_steps} user-steps in "
           f"{s.dispatches} dispatches ({s.user_steps_per_s:.2f} "
           f"user-steps/s)")
    return engine, results
