from repro.data.synthetic import (lm_batches, sst2_batches,
                                  synthetic_lm_corpus, synthetic_sst2)
from repro.data.pipeline import DataPipeline

__all__ = ["lm_batches", "sst2_batches", "synthetic_lm_corpus",
           "synthetic_sst2", "DataPipeline"]
