"""Hash-RNG unit tests: determinism, tiling consistency, distribution."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rng as zrng


def test_determinism():
    a = zrng.z_field(jnp.uint32(7), 11, (64, 32))
    b = zrng.z_field(jnp.uint32(7), 11, (64, 32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_seed_and_salt_decorrelate():
    a = zrng.z_field(jnp.uint32(7), 11, (4096,))
    b = zrng.z_field(jnp.uint32(8), 11, (4096,))
    c = zrng.z_field(jnp.uint32(7), 12, (4096,))
    assert abs(float(jnp.mean(a * b))) < 0.1
    assert abs(float(jnp.mean(a * c))) < 0.1


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_tile_offsets_match_full_array(dist):
    """A tile generated with offsets == the same slice of the full field
    (the property the Pallas kernels rely on)."""
    full = zrng.z_field(jnp.uint32(3), 99, (64, 48), dist=dist)
    tile = zrng.z_field(jnp.uint32(3), 99, (16, 16), dist=dist,
                        offsets=(32, 16))
    np.testing.assert_array_equal(np.asarray(full[32:48, 16:32]),
                                  np.asarray(tile))


def test_rademacher_stats():
    z = np.asarray(zrng.rademacher_field(jnp.uint32(0), 5, (128, 128)))
    assert set(np.unique(z)) == {-1.0, 1.0}
    assert abs(z.mean()) < 0.02


def test_gaussian_stats():
    z = np.asarray(zrng.gaussian_field(jnp.uint32(0), 5, (256, 256)))
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    assert np.isfinite(z).all()


def test_fold_seed_distinct():
    s = jnp.uint32(1234)
    folds = {int(zrng.fold_seed(s, k)) for k in range(100)}
    assert len(folds) == 100


def test_high_rank_leaves():
    z = zrng.z_field(jnp.uint32(1), 2, (3, 4, 5, 6, 2))
    assert z.shape == (3, 4, 5, 6, 2)
    assert np.isfinite(np.asarray(z)).all()
