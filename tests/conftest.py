import os
import sys

# Tests see the default 1-device CPU backend (the dry-run sets its own
# XLA_FLAGS in a separate process -- never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
