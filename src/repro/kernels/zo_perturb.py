"""Pallas TPU kernels for zeroth-order perturbation (the paper's hot spot).

PocketLLM's memory claim rests on never materializing the perturbation
``z``. On a phone that means regenerating from a CPU PRNG into registers;
the TPU-native rendering is to regenerate ``z`` *tiles in VMEM* inside the
kernel so z never exists in HBM at all:

  * ``zo_add_kernel``     -- W' = W + coeff * z(seed)   (perturb / fused
                             restore+update sweep of a MeZO step)
  * ``zo_matmul_kernel``  -- Y  = X @ (W + coeff * z(seed))  (perturbed
                             forward matmul: the perturbation is fused
                             into the MXU pipeline; W is read once and z
                             costs zero HBM bytes)

Both kernels also take an optional per-output-channel ``scale`` vector
marking W as an *int8 quantized base* (optim/quant.py): the tile is then
dequantized in VMEM (``w*scale``) before the perturbation/dot, so the
resident base stays ~1 byte/param in HBM and the dequant costs zero extra
memory traffic.

The RNG is the same counter-based avalanche hash as repro.core.rng, keyed
by absolute (row, col) coordinates, so full-array references in ref.py
reproduce kernel tiles bit-exactly for any BlockSpec tiling.

Block shapes: (128, 128)-aligned tiles for the MXU; zo_add is a pure
VPU/memory kernel and uses (256, 256) tiles to amortize grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_U32 = jnp.uint32

# keep in sync with repro.core.rng (duplicated to keep the kernel module
# importable without touching jax device state through core's __init__)
_DIM_PRIMES = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D)


def _avalanche(x):
    x = x ^ (x >> 15)
    x = x * _U32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * _U32(0x297A2D39)
    x = x ^ (x >> 15)
    return x


def _tile_z(seed, salt, shape, row0, col0, dist: str,
            prime_offset: int = 0, prehashed: bool = False):
    """z tile of ``shape`` at absolute offset (row0, col0), f32.

    prehashed: ``seed`` is already ``avalanche(step_seed ^ salt)`` (plus any
    leading-coordinate folds -- core.rng.leaf_base / fold_leading), letting a
    2-D kernel tile reproduce the field of a slice of a stacked (L, m, n)
    leaf. prime_offset selects the per-dimension primes accordingly.
    """
    h = jnp.asarray(seed, _U32) if prehashed \
        else _avalanche(jnp.asarray(seed, _U32) ^ _U32(salt))
    ri = jax.lax.broadcasted_iota(_U32, shape, 0) + jnp.asarray(row0, _U32)
    ci = jax.lax.broadcasted_iota(_U32, shape, 1) + jnp.asarray(col0, _U32)
    h = _avalanche(h ^ (ri * _U32(_DIM_PRIMES[prime_offset])))
    h = _avalanche(h ^ (ci * _U32(_DIM_PRIMES[prime_offset + 1])))
    if dist == "rademacher":
        return 1.0 - 2.0 * (h >> 31).astype(jnp.float32)
    # gaussian (Box-Muller)
    h2 = _avalanche(h ^ _U32(0x68E31DA4))
    u1 = ((h >> 8).astype(jnp.float32) + 1.0) * (1.0 / 16777216.0)
    u2 = (h2 >> 8).astype(jnp.float32) * (1.0 / 16777216.0)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(6.283185307179586 * u2)


# ---------------------------------------------------------------------------
# W + coeff * z


def _pick(dim: int, want: int) -> int:
    """Largest block size <= want that divides dim (prefers lane-aligned)."""
    b = min(want, dim)
    while dim % b:
        b -= 1
    return b


def _zo_add_kernel(seed_ref, coeff_ref, w_ref, o_ref, *, salt, bm, bn, dist,
                   prime_offset, prehashed):
    i, j = pl.program_id(0), pl.program_id(1)
    z = _tile_z(seed_ref[0], salt, (bm, bn), i * bm, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (w + coeff_ref[0] * z).astype(o_ref.dtype)


def _zo_add_q_kernel(seed_ref, coeff_ref, w_ref, s_ref, o_ref, *, salt, bm,
                     bn, dist, prime_offset, prehashed):
    """Quantized-base variant: W is int8, s the (1, bn) per-channel scale
    tile; dequant happens in VMEM, fused with the perturbation."""
    i, j = pl.program_id(0), pl.program_id(1)
    z = _tile_z(seed_ref[0], salt, (bm, bn), i * bm, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] = (w + coeff_ref[0] * z).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("salt", "dist", "block", "interpret",
                                    "prime_offset", "prehashed"))
def zo_add(w, seed, salt: int, coeff, dist: str = "rademacher",
           block=(256, 256), interpret: bool = False,
           prime_offset: int = 0, prehashed: bool = False, scale=None):
    """W + coeff*z for a 2-D leaf; z regenerated in VMEM, never in HBM.

    scale: per-output-channel (N,) f32 scales marking ``w`` as an int8
    quantized base -- the kernel then computes ``w*scale + coeff*z``
    (dequant fused into the same tile pass; output f32). HBM reads drop
    to ~1/4: the int8 values plus an (N,) scale vector.
    """
    m, n = w.shape
    bm, bn = _pick(m, block[0]), _pick(n, block[1])
    grid = (m // bm, n // bn)
    seed = jnp.asarray(seed, _U32).reshape(1)
    coeff = jnp.asarray(coeff, jnp.float32).reshape(1)
    if scale is None:
        return pl.pallas_call(
            functools.partial(_zo_add_kernel, salt=salt, bm=bm, bn=bn,
                              dist=dist, prime_offset=prime_offset,
                              prehashed=prehashed),
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),  # seed
                pl.BlockSpec(memory_space=pltpu.SMEM),  # coeff
                pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
            interpret=interpret,
        )(seed, coeff, w)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, n)
    return pl.pallas_call(
        functools.partial(_zo_add_q_kernel, salt=salt, bm=bm, bn=bn,
                          dist=dist, prime_offset=prime_offset,
                          prehashed=prehashed),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seed
            pl.BlockSpec(memory_space=pltpu.SMEM),  # coeff
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(seed, coeff, w, scale)


# ---------------------------------------------------------------------------
# user-batched W[u] + coeff[u] * z(seed[u])


def _zo_add_users_kernel(seed_ref, coeff_ref, w_ref, o_ref, *, salt, bm, bn,
                         dist, prime_offset, prehashed):
    u, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    z = _tile_z(seed_ref[u], salt, (bm, bn), i * bm, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[0].astype(jnp.float32)
    o_ref[0] = (w + coeff_ref[u] * z).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("salt", "dist", "block", "interpret",
                                    "prime_offset", "prehashed"))
def zo_add_users(w, seeds, salt: int, coeffs, dist: str = "rademacher",
                 block=(256, 256), interpret: bool = False,
                 prime_offset: int = 0, prehashed: bool = False):
    """User-batched :func:`zo_add`: W (U, M, N) per-user stacked leaves,
    seeds/coeffs (U,) -- ``out[u] = W[u] + coeffs[u] * z(seeds[u])``.

    One dispatch sweeps every user's leaf; per-tile arithmetic (block
    shapes, z regeneration, accumulation) is identical to U scalar
    :func:`zo_add` calls, so the batch is bit-exact with the loop. The
    user axis rides the grid's *leading* (outermost, slowest) dimension:
    lane-local tile order is preserved and the (U,) seed/coeff vectors
    sit in SMEM, indexed by ``program_id(0)``.
    """
    u, m, n = w.shape
    bm, bn = _pick(m, block[0]), _pick(n, block[1])
    seeds = jnp.asarray(seeds, _U32).reshape(u)
    coeffs = jnp.asarray(coeffs, jnp.float32).reshape(u)
    return pl.pallas_call(
        functools.partial(_zo_add_users_kernel, salt=salt, bm=bm, bn=bn,
                          dist=dist, prime_offset=prime_offset,
                          prehashed=prehashed),
        grid=(u, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seeds (U,)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # coeffs (U,)
            pl.BlockSpec((1, bm, bn), lambda uu, i, j: (uu, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda uu, i, j: (uu, i, j)),
        out_shape=jax.ShapeDtypeStruct((u, m, n), w.dtype),
        interpret=interpret,
    )(seeds, coeffs, w)


# ---------------------------------------------------------------------------
# X @ (W + coeff * z)


def _zo_matmul_kernel(seed_ref, coeff_ref, x_ref, w_ref, o_ref, acc_ref, *,
                      salt, bk, bn, n_k, dist, prime_offset, prehashed):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    z = _tile_z(seed_ref[0], salt, (bk, bn), k * bk, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32) + coeff_ref[0] * z
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _zo_matmul_q_kernel(seed_ref, coeff_ref, x_ref, w_ref, s_ref, o_ref,
                        acc_ref, *, salt, bk, bn, n_k, dist, prime_offset,
                        prehashed):
    """Quantized-base variant of :func:`_zo_matmul_kernel`: the W tile
    arrives int8, the (1, bn) per-channel scale tile rides along, and
    ``dequant + coeff*z`` happens in VMEM before the MXU dot -- the base
    never exists dequantized in HBM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    z = _tile_z(seed_ref[0], salt, (bk, bn), k * bk, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32) * s_ref[...] + coeff_ref[0] * z
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("salt", "dist", "blocks", "interpret",
                                    "prime_offset", "prehashed"))
def zo_matmul(x, w, seed, salt: int, coeff, dist: str = "rademacher",
              blocks=(128, 128, 128), interpret: bool = False,
              prime_offset: int = 0, prehashed: bool = False, scale=None):
    """Y = X @ (W + coeff * z(seed)). X: (M, K), W: (K, N).

    The perturbed weight tile lives only in VMEM: HBM traffic is exactly
    the unperturbed matmul's (X, W read once; Y written once).

    scale: per-output-channel (N,) f32 scales marking ``w`` as an int8
    quantized base -- the kernel then computes
    ``X @ (w*scale + coeff*z)`` with dequantization fused into the same
    VMEM tile pass (weight HBM reads ~1/4 of the f32 kernel's, z still
    zero bytes; the prehashed-salt scheme is untouched).

    prehashed/prime_offset: see :func:`_tile_z` -- lets the kernel compute
    the perturbed forward for one layer-slice of a scan-stacked (L, K, N)
    leaf while staying bit-exact with the full-leaf reference field.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = _pick(m, blocks[0]), _pick(k, blocks[1]), _pick(n, blocks[2])
    grid = (m // bm, n // bn, k // bk)
    seed = jnp.asarray(seed, _U32).reshape(1)
    coeff = jnp.asarray(coeff, jnp.float32).reshape(1)
    if scale is None:
        kern = functools.partial(_zo_matmul_kernel, salt=salt, bk=bk, bn=bn,
                                 n_k=grid[2], dist=dist,
                                 prime_offset=prime_offset,
                                 prehashed=prehashed)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(seed, coeff, x, w)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, n)
    kern = functools.partial(_zo_matmul_q_kernel, salt=salt, bk=bk, bn=bn,
                             n_k=grid[2], dist=dist,
                             prime_offset=prime_offset, prehashed=prehashed)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(seed, coeff, x, w, scale)


# ---------------------------------------------------------------------------
# user-batched X[u] @ (W + coeff[u] * z(seed[u])) -- one resident base,
# B users' perturbed forwards in one dispatch


def _zo_matmul_users_kernel(seed_ref, coeff_ref, x_ref, w_ref, o_ref,
                            acc_ref, *, salt, bk, bn, n_k, dist,
                            prime_offset, prehashed):
    u, j, k = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _tile_z(seed_ref[u], salt, (bk, bn), k * bk, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32) + coeff_ref[u] * z
    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _zo_matmul_users_q_kernel(seed_ref, coeff_ref, x_ref, w_ref, s_ref,
                              o_ref, acc_ref, *, salt, bk, bn, n_k, dist,
                              prime_offset, prehashed):
    """Quantized shared base: the int8 W tile is read once per (j, k)
    revisit and dequantized in VMEM with each user's perturbation --
    U tenants' forwards never materialize a f32 base in HBM."""
    u, j, k = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _tile_z(seed_ref[u], salt, (bk, bn), k * bk, j * bn, dist,
                prime_offset, prehashed)
    w = w_ref[...].astype(jnp.float32) * s_ref[...] + coeff_ref[u] * z
    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("salt", "dist", "blocks", "interpret",
                                    "prime_offset", "prehashed"))
def zo_matmul_users(x, w, seeds, salt: int, coeffs,
                    dist: str = "rademacher", blocks=(128, 128, 128),
                    interpret: bool = False, prime_offset: int = 0,
                    prehashed: bool = False, scale=None):
    """User-batched :func:`zo_matmul`: ``Y[u] = X[u] @ (W +
    coeffs[u] * z(seeds[u]))``. X: (U, M, K); W: (K, N), SHARED across
    users (the single resident base); seeds/coeffs: (U,).

    This is the multi-tenant hot path: one dispatch evaluates B users'
    perturbed forwards against one copy of the weights. The user axis is
    the grid's outermost dimension with the k-reduction innermost, and
    block sizes match the scalar kernel's, so each lane's accumulation
    order -- and therefore its bits -- is identical to a lone
    :func:`zo_matmul` call with that user's (seed, coeff).

    scale: per-output-channel (N,) f32 scales marking ``w`` as an int8
    quantized base; dequant fuses into the same VMEM tile pass, so U
    tenants share ~1 byte/param of resident weight HBM.
    """
    u, m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = _pick(m, blocks[0]), _pick(k, blocks[1]), _pick(n, blocks[2])
    grid = (u, m // bm, n // bn, k // bk)
    seeds = jnp.asarray(seeds, _U32).reshape(u)
    coeffs = jnp.asarray(coeffs, jnp.float32).reshape(u)
    if scale is None:
        kern = functools.partial(_zo_matmul_users_kernel, salt=salt, bk=bk,
                                 bn=bn, n_k=grid[3], dist=dist,
                                 prime_offset=prime_offset,
                                 prehashed=prehashed)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),  # seeds (U,)
                pl.BlockSpec(memory_space=pltpu.SMEM),  # coeffs (U,)
                pl.BlockSpec((1, bm, bk), lambda uu, i, j, kk: (uu, i, kk)),
                pl.BlockSpec((bk, bn), lambda uu, i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda uu, i, j, kk: (uu, i, j)),
            out_shape=jax.ShapeDtypeStruct((u, m, n), x.dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(seeds, coeffs, x, w)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, n)
    kern = functools.partial(_zo_matmul_users_q_kernel, salt=salt, bk=bk,
                             bn=bn, n_k=grid[3], dist=dist,
                             prime_offset=prime_offset, prehashed=prehashed)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bm, bk), lambda uu, i, j, kk: (uu, i, kk)),
            pl.BlockSpec((bk, bn), lambda uu, i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda uu, i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda uu, i, j, kk: (uu, i, j)),
        out_shape=jax.ShapeDtypeStruct((u, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(seeds, coeffs, x, w, scale)
