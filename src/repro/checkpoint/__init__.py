from repro.checkpoint.store import load_params, save_params, latest_step
from repro.checkpoint.replay_log import ReplayLog
from repro.checkpoint.manager import CheckpointManager

__all__ = ["load_params", "save_params", "latest_step", "ReplayLog",
           "CheckpointManager"]
