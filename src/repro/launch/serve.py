"""Serving launcher: thin CLI over the personalized serving subsystem.

The engine lives in :mod:`repro.serve` (AdapterStore + fused prefill +
continuous-batching decode); this module keeps (a) ``serve()``, the
reference per-token generation loop the parity tests pin the engine
against, and (b) a CLI that builds an engine, loads per-user ZO adapters
from replay logs, and serves a synthetic request mix:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 4 --prompt-len 16 --gen 8 \
      --adapter alice=/tmp/ckpt_alice --adapter bob=/tmp/ckpt_bob
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import ALL_ARCHS, get_config
from repro.core import MezoConfig
from repro.models import build_model
from repro.serve import (AdapterStore, Request, ServeEngine, sample_topk,
                         step_keys)


def serve(cfg, params, prompts: np.ndarray, gen: int, greedy: bool = True,
          topk: int = 8, seed: int = 0):
    """Reference per-token loop: prefill token-by-token through the
    decode cell, then decode. Kept as the parity oracle for the fused
    prefill path (tests/test_serve.py) and as the simplest possible
    serving implementation.

    prompts: (B, P) int32. Returns (B, gen) generated tokens. Sampling
    is seeded: one key split per step, folded per slot -- runs with
    different ``seed`` values draw independent streams.
    """
    model = build_model(cfg)
    bsz, plen = prompts.shape
    cache = model.init_cache(bsz, plen + gen)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    key = jax.random.PRNGKey(seed)

    toks = jnp.asarray(prompts)
    out = []
    last = None
    for t in range(plen + gen - 1):
        if t < plen:
            cur = toks[:, t:t + 1]
        else:
            cur = last
            out.append(np.asarray(cur))
        logits, cache = step(params, cache, cur, jnp.int32(t))
        if greedy:
            last = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        else:
            key, slot_keys = step_keys(key, bsz)
            last = sample_topk(slot_keys, logits[:, -1, :], topk)[:, None]
    out.append(np.asarray(last))
    return np.concatenate(out, axis=1)[:, :gen]


# one representative arch per decode-capable family -- the smoke path for
# "does family X serve end-to-end?" (--family encdec exercises the
# enc-dec fused prefill the block-registry runtime added)
FAMILY_ARCHS = {
    "dense": "gemma-2b",
    "moe": "granite-moe-1b-a400m",
    "hybrid": "jamba-v0.1-52b",
    "ssm": "rwkv6-7b",
    "encdec": "whisper-base",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--family", default=None, choices=sorted(FAMILY_ARCHS),
                    help="serve this family's representative arch "
                         "(overrides --arch): " + ", ".join(
                             f"{f}={a}" for f, a in FAMILY_ARCHS.items()))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="load BASE params from this checkpoint dir")
    ap.add_argument("--adapter", action="append", default=[],
                    metavar="USER=CKPT_DIR",
                    help="register USER's replay log as a ZO adapter "
                         "(repeatable); requests round-robin over users")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--sample", action="store_true",
                    help="seeded top-k sampling instead of greedy")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dist", default="rademacher",
                    choices=("rademacher", "gaussian"),
                    help="perturbation dist the adapters were trained with")
    ap.add_argument("--weight-decay", type=float, default=0.0,
                    help="weight decay the adapters were trained with "
                         "(replay must apply the same decay coefficient)")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="adapter-store byte budget for materialized trees")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: attention K/V in a shared page "
                         "pool with per-slot page tables (decode reads "
                         "only live pages via the flash-decoding kernel)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="total pool pages incl. the trash page "
                         "(default: slots x ceil(max_len/page_size) + 1, "
                         "i.e. dense capacity)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="self-speculative decoding (needs --paged): the "
                         "frozen base drafts up to K tokens per round into "
                         "the slot's shared KV pages, base+delta verifies "
                         "them in one batched window call; greedy output "
                         "is bit-identical to plain decoding")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="N",
                    help="chunked prefill (needs --paged): admissions "
                         "advance at most N prompt tokens per engine step, "
                         "written straight into the slot's reserved KV "
                         "pages, while decoding slots keep stepping -- no "
                         "whole-prompt admission stall; greedy output is "
                         "bit-identical to whole-prompt prefill and "
                         "composes with --spec-k")
    args = ap.parse_args()

    if args.family:
        args.arch = FAMILY_ARCHS[args.family]
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        step = store.latest_step(args.ckpt_dir)
        if step is not None:
            params = store.load_params(args.ckpt_dir, step, params)
            print(f"[serve] loaded base checkpoint step {step}")

    adapters = AdapterStore(
        params, MezoConfig(dist=args.dist, weight_decay=args.weight_decay),
        cache_bytes=(int(args.cache_mb * 2**20) if args.cache_mb else None))
    users = []
    for spec in args.adapter:
        user, _, ckpt = spec.partition("=")
        if not ckpt:
            raise SystemExit(f"--adapter wants USER=CKPT_DIR, got {spec!r}")
        ad = adapters.import_checkpoint(user, ckpt)
        users.append(user)
        print(f"[serve] adapter {user!r}: {ad.n_steps} steps, "
              f"{ad.nbytes} bytes")
    if not users:
        users = [None]                     # base weights only

    engine = ServeEngine(cfg, adapters, n_slots=args.slots,
                         max_len=args.prompt_len + args.gen,
                         seed=args.seed, paged=args.paged,
                         page_size=args.page_size,
                         pool_pages=args.pool_pages,
                         spec_k=args.spec_k,
                         prefill_chunk=args.prefill_chunk)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                           dtype=np.int32)
    for i in range(args.requests):
        engine.submit(Request(prompt=prompts[i], max_new=args.gen,
                              user=users[i % len(users)],
                              greedy=not args.sample, topk=args.topk,
                              temperature=args.temperature))
    t0 = time.perf_counter()
    completions = engine.run()
    dt = time.perf_counter() - t0
    for c in completions:
        tag = c.user if c.user is not None else "base"
        print(f"[serve] rid={c.rid} user={tag}: {c.tokens.tolist()}")
    st = engine.stats
    paged_note = (f" | paged: {engine.pool_pages} pages x "
                  f"{engine.page_size} tok, peak in use "
                  f"{st.peak_pages_in_use}" if engine.paged else "")
    if engine.spec_k:
        paged_note += (f" | spec k={engine.spec_k}: accepted "
                       f"{st.spec_accepted}/{st.spec_drafted} drafts "
                       f"({st.spec_accept_rate:.0%}) in "
                       f"{st.decode_steps} rounds")
    if engine.prefill_chunk:
        paged_note += f" | chunked prefill C={engine.prefill_chunk}"
    n_done = max(len(completions), 1)
    lat_note = (f" | ttft avg {st.ttft_s / n_done * 1e3:.0f}ms "
                f"(queue {st.queue_wait_s / n_done * 1e3:.0f}ms) | "
                f"decode stall {st.decode_stall_s:.2f} slot-s")
    print(f"[serve] {args.requests} reqs x ({args.prompt_len} prompt + "
          f"{args.gen} gen) in {dt:.2f}s | prefill {st.prefill_tps:.0f} "
          f"tok/s | decode {st.decode_tps:.0f} tok/s | "
          f"adapter materializations: {adapters.stats['misses']} "
          f"(hits {adapters.stats['hits']})" + lat_note + paged_note)


if __name__ == "__main__":
    main()
