"""MoE dispatch correctness: sort-based dispatch == direct dense eval."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as MoE
from repro.models.config import ModelConfig


def _dense_reference(cfg, p, x):
    """Directly evaluate all experts for all tokens, take top-k mixture."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.topk)
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
    # all experts on all tokens (interleaved gated layout (E, D, F, 2))
    h = jnp.einsum("td,edfg->tefg", xf, p["w_in"])
    u, g = h[..., 0], h[..., 1]
    h = u * jax.nn.silu(g)
    y_all = jnp.einsum("tef,efd->ted", h, p["w_out"])
    out = jnp.zeros_like(xf)
    for k in range(cfg.topk):
        out = out + gate[:, k:k + 1] * jnp.take_along_axis(
            y_all, idx[:, k][:, None, None], axis=1)[:, 0]
    return out.reshape(b, s, d)


def _cfg(**kw):
    base = dict(n_layers=1, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                vocab=64, family="moe", n_experts=8, topk=2, expert_dff=48,
                capacity_factor=8.0, act="swiglu", dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_dispatch_matches_dense_reference():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = MoE.moe_init(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    got, aux = MoE.moe_apply(cfg, p, x)
    want = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_capacity_drops_tokens_gracefully():
    cfg = _cfg(capacity_factor=0.05)   # tiny capacity -> heavy drops
    p = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    got, _ = MoE.moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(got)).all()
    # dropped tokens contribute zero (residual carries them), so the
    # output norm must be below the no-drop case
    cfg2 = _cfg(capacity_factor=8.0)
    full, _ = MoE.moe_apply(cfg2, p, x)
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(full)) + 1e-3


def test_topk_weights_normalized():
    cfg = _cfg(topk=4)
    p = MoE.moe_init(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model)) * 10
    got, _ = MoE.moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(got)).all()


def test_shared_expert_adds_dense_path():
    cfg = _cfg(n_shared_experts=1)
    p = MoE.moe_init(cfg, jax.random.PRNGKey(4))
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    got, _ = MoE.moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(got)).all()


def test_kimi_and_granite_moe_shapes():
    for arch in ("kimi-k2-1t-a32b", "granite-moe-1b-a400m"):
        cfg = get_config(arch)
        rcfg = cfg.reduced()
        p = MoE.moe_init(rcfg, jax.random.PRNGKey(0))
        assert p["w_in"].shape[0] == rcfg.n_experts
        assert p["w_in"].shape[-1] == 2  # interleaved gated layout
