"""Continuous-batching decode engine over per-user ZO adapters.

A fixed table of ``n_slots`` sequence slots shares one batched decode
cache. Requests queue up; whenever a slot is free the next request is
admitted *mid-flight*: its adapter is materialized through the
:class:`~repro.serve.adapters.AdapterStore`, its prompt is prefilled in
one fused call (``model.prefill`` -- wired for every decode-capable
family, enc-dec included; a per-token fallback remains as a safety net
for models built without one), and the cache rows are scattered into
the slot.
Finished sequences free their slot on the spot -- the engine never
drains the whole batch to admit new work.

Every decode step advances ALL active slots one token, each at its own
position (``decode_step`` takes a per-slot ``pos`` vector). Slots served
by different adapters are handled with one decode dispatch per distinct
active adapter, masked-merged into the shared cache -- compute cost per
step scales with the number of *distinct* adapters in flight, the
classic multi-model batching tradeoff (cf. S-LoRA-style adapter
batching), except here an "adapter" is a replayed scalar log, not extra
weights in the batch.

Paged KV (``paged=True``): instead of every slot pre-allocating a dense
(max_len, KV, hd) strip per layer, attention K/V lives in a shared pool
of fixed-size pages with a per-slot page table. Pages are *reserved* at
admission (the request's worst-case ``ceil((plen+max_new)/page_size)``,
so mid-flight growth can never dead-lock) but only *allocated* as the
sequence actually reaches them, and freed the moment the slot finishes.
Slot count is then bounded by tokens resident, not ``slots x max_len``:
a pool sized for 4 dense max-len slots holds every short request that
fits, concurrently. Decode reads only live pages -- the flash-decoding
kernel (TPU) / gather reference skips each slot's dead tail -- with the
live page count bucketed to powers of two so the step stays a handful
of compiled shapes. Physical page 0 is the trash page: freed slots'
table rows and masked-out adapter lanes scatter there, which keeps the
multi-adapter merge a leaf-name split (pool leaves: take new; dense
recurrent leaves: masked lane select) instead of a page-level scatter.

Families without pageable state (rwkv6: O(1) recurrent state per slot)
run ``paged=True`` as the dense layout -- same admission, same tokens.

Chunked prefill (``prefill_chunk=C``, paged mode only): whole-prompt
admission is an *admission stall* -- every resident decode slot freezes
for the full prompt's prefill, and the prompt transits a throwaway
dense B=1 cache that is then scattered page-by-page into the pool
(``install_paged``). Chunked mode instead runs at most one admission at
a time and advances it at most ``C`` prompt tokens per engine step,
each chunk written *straight into the slot's reserved pages* by
``model.prefill_chunk`` (flash-prefill kernel on TPU) -- no dense
intermediate, no install scatter -- while every decoding slot still
advances one token per step (Sarathi-style mixed batching). The
admission reservation already covers every chunk's pages, so chunking
cannot deadlock. Tail chunks decompose into powers of two (a 13-token
tail runs as 8+4+1) so the chunk dispatch stays a handful of compiled
shapes without padding -- padded tokens would corrupt recurrent
(mamba/rwkv) state, which advances dense through the chunk at the
slot's lane. While a chunked prefill is in flight, decode always takes
the masked dispatch: the prefilling slot's page-table row points at
real pages and its recurrent lane is mid-advance, so an unmasked
all-slots decode would write garbage through both. Greedy output is
bit-identical to whole-prompt admission; the per-admission key split
happens once in both modes.

The engine is family-agnostic: the block-registry runtime's unified
StateCache puts every dense leaf at (n_layers, B, ...) -- batch on axis
1 for every family -- so slot scatter/merge is one ``jax.tree.map``,
with no per-family axis table. Jitted serving entry points are cached
per Model (see ``_serving_fns``): constructing an engine re-uses the
compiled decode/prefill/install executables instead of re-tracing them,
which -- together with keeping the sampler's key-split off the
greedy-only hot path -- is where the pre-paging decode baseline lost
most of its step budget (table3).

Speculative decoding (``spec_k``, paged mode only): the engine's own
frozen base weights (``store.materialize(None)`` -- the int8 base when
quantized) act as the draft model, so speculation adds ZERO extra weight
bytes. Each round the base drafts up to ``k`` tokens greedily, writing
its K/V into the slot's already-reserved pages; one batched
``verify_window`` call then scores all k+1 window positions with the
target (base+delta) model, *overwriting* the window's K/V with the
target's own -- so the pool afterwards holds exactly what a sequential
target decode would have cached and verification is exact. The longest
draft prefix matching the target's greedy choices is accepted plus the
target's correction/bonus token; greedy output is bit-identical to the
non-speculative engine. Rejected positions need no data rollback: reads
mask ``k_pos <= pos`` and the next round overwrites stale entries before
they are read. Recurrent leaves (hybrid families) cannot be overwritten
in place, so verify stacks one state snapshot per window offset and the
commit selects each slot's accepted offset -- the recurrent analogue of
the page-table rollback. Sampled slots use speculative rejection
sampling against the greedy draft (accept token x w.p. p(x); resample
from the residual on rejection), which preserves the target's top-k
sampling distribution. The draft's worst-case write position ``pos +
k`` never outgrows the admission reservation because the per-slot draft
length is capped at ``remaining``. MoE verify windows share expert
capacity across window offsets, so spec parity is only pinned for dense
and hybrid families.

MoE caveat: expert capacity is contended across the whole slot batch, so
a slot's logits can depend on what its neighbors decode -- inherent to
capacity-bounded MoE serving, not to this engine.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batching import masked_merge
from repro.models import build_model
from repro.serve import sampling
from repro.serve.adapters import AdapterStore

PyTree = Any


@dataclasses.dataclass
class Request:
    """One generation request, tagged with the adapter that serves it."""
    prompt: np.ndarray            # (P,) int32 token ids
    max_new: int
    user: Optional[str] = None    # adapter id; None -> base weights
    greedy: bool = True
    topk: int = 0                 # used when greedy=False
    temperature: float = 1.0
    rid: int = -1                 # assigned by submit()
    submit_ts: Optional[float] = None     # stamped by submit()


@dataclasses.dataclass
class Completion:
    rid: int
    user: Optional[str]
    prompt: np.ndarray
    tokens: np.ndarray            # (n_generated,) int32
    accept_rate: Optional[float] = None   # draft acceptance (spec mode)
    queue_wait_s: float = 0.0     # submit -> admission start
    ttft_s: float = 0.0           # submit -> first token picked


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_s: float = 0.0
    decode_tokens: int = 0
    decode_s: float = 0.0
    decode_steps: int = 0
    admitted: int = 0
    finished: int = 0
    peak_active_slots: int = 0
    peak_pages_in_use: int = 0    # paged mode only (excludes trash page)
    spec_drafted: int = 0         # draft tokens proposed (spec mode)
    spec_accepted: int = 0        # draft tokens accepted and committed
    # slot-seconds active decode slots sat idle while admission prefill
    # work ran. Whole-prompt admission accrues the full prompt's prefill
    # per resident decoder in one burst; chunked admission accrues one
    # chunk at a time, so nearly-finished slots drain instead of
    # freezing behind a long prompt.
    decode_stall_s: float = 0.0
    queue_wait_s: float = 0.0     # summed over admissions
    ttft_s: float = 0.0           # summed over admissions

    @staticmethod
    def _rate(num: float, den: float) -> float:
        return num / den if den > 0 else 0.0

    @property
    def prefill_tps(self) -> float:
        return self._rate(self.prefill_tokens, self.prefill_s)

    @property
    def decode_tps(self) -> float:
        return self._rate(self.decode_tokens, self.decode_s)

    @property
    def spec_accept_rate(self) -> float:
        return self._rate(self.spec_accepted, self.spec_drafted)


def _merge_paged(cache, new, mask):
    """Multi-adapter merge for a paged cache: pool leaves were written
    through the page table (masked lanes scattered into the trash page),
    so the new pool is already correct for every slot; dense (L, B, ...)
    leaves lane-select like the unpaged engine."""
    mask = jnp.asarray(mask, bool)

    def pick(path, o, n):
        if str(getattr(path[-1], "key", path[-1])).endswith("_pages"):
            return n
        return jnp.where(jnp.reshape(mask, (1, -1) + (1,) * (o.ndim - 2)),
                         n, o)

    return jax.tree_util.tree_map_with_path(pick, cache, new)


# per-Model jitted serving entry points. build_model memoizes Model on
# the config, so every engine over the same config shares ONE set of
# compiled executables -- engine construction costs no re-trace.
_SERVING_FNS: Dict[int, Dict[str, Any]] = {}


def _serving_fns(model) -> Dict[str, Any]:
    fns = _SERVING_FNS.get(id(model))
    if fns is not None:
        return fns
    decode_step = model.decode_step

    # the slot-table cache is donated on every hot-path call: decode
    # updates it in place instead of copying the full (n_slots,
    # max_len) KV per token (the reference serve() loop donates too)
    @partial(jax.jit, donate_argnums=(1,))
    def decode_all(params, cache, toks, pos):
        return decode_step(params, cache, toks, pos)

    @partial(jax.jit, donate_argnums=(1,))
    def decode_masked(params, cache, toks, pos, mask):
        logits, new = decode_step(params, cache, toks, pos)
        # every StateCache leaf batches on axis 1 (same ragged-slot
        # helper the TrainEngine uses on its axis-0 user stack)
        return logits, masked_merge(cache, new, mask, axis=1)

    @partial(jax.jit, donate_argnums=(1,))
    def decode_all_paged(params, cache, toks, pos, pages):
        return decode_step(params, cache, toks, pos, pages=pages)

    @partial(jax.jit, donate_argnums=(1,))
    def decode_masked_paged(params, cache, toks, pos, pages, mask):
        logits, new = decode_step(params, cache, toks, pos, pages=pages,
                                  write_mask=mask)
        return logits, _merge_paged(cache, new, mask)

    @partial(jax.jit, donate_argnums=(0,))
    def install(cache, prefill_cache, slot):
        """Scatter a B=1 prefilled cache into slot row ``slot``. Rows
        may be shorter than the slot cache along trailing axes (the
        admission buckets ``fresh_len`` to a power of two): the update
        writes the row-sized prefix and the dead tail past ``pos`` is
        never read."""

        def put(c, row):
            return jax.lax.dynamic_update_slice(
                c, row.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))

        return jax.tree.map(put, cache, prefill_cache)

    @partial(jax.jit, donate_argnums=(0,))
    def install_paged(cache, prefill_cache, phys, slot):
        """Scatter a B=1 prefilled *dense* cache into the paged slot:
        pool leaves (``X_pages``) page their dense twin ``X`` into the
        slot's physical pages; dense leaves install into row ``slot``."""
        fresh = {jax.tree_util.keystr(p): v for p, v in
                 jax.tree_util.tree_leaves_with_path(prefill_cache)}
        npg = phys.shape[0]

        def put(path, c):
            ks = jax.tree_util.keystr(path)
            name = str(getattr(path[-1], "key", path[-1]))
            if name.endswith("_pages"):
                row = fresh[ks.replace(name, name[:-len("_pages")])]
                ps = c.shape[2]
                src = row[:, 0, :npg * ps].reshape(
                    (row.shape[0], npg, ps) + row.shape[3:])
                return c.at[:, phys].set(src.astype(c.dtype))
            return c.at[:, slot].set(
                jnp.take(fresh[ks], 0, axis=1).astype(c.dtype))

        return jax.tree_util.tree_map_with_path(put, cache)

    def _pool_or(path, old, new):
        """Leaf-name split shared by the speculative fns: pool leaves
        (written through the page table) take the new buffer, everything
        else keeps ``old``."""
        if str(getattr(path[-1], "key", path[-1])).endswith("_pages"):
            return new
        return old

    draft_spec = verify_spec = commit_spec = None
    verify_window = model.verify_window
    if verify_window is not None:
        @partial(jax.jit, static_argnums=(6,), donate_argnums=(1,))
        def draft_spec(params, cache, last, pos, pages, draft_len, k):
            """Greedy-draft ``k`` tokens per slot with the (base) params:
            k chained decode steps inside one dispatch. Slots draft only
            ``draft_len`` tokens (excess writes land in the trash page and
            the proposed token freezes). The draft's K/V goes into the
            shared pages -- verify overwrites it -- while its dense
            recurrent-state advance is discarded (the target's verify
            scan re-derives it exactly)."""
            def step(carry, i):
                toks, c = carry
                lg, c = decode_step(params, c, toks[:, None], pos + i,
                                    pages=pages, write_mask=i < draft_len)
                nxt = jnp.argmax(lg[:, -1, :], axis=-1).astype(toks.dtype)
                toks = jnp.where(i < draft_len, nxt, toks)
                return (toks, c), toks

            (_, newc), drafts = jax.lax.scan(
                step, (last, cache), jnp.arange(k, dtype=jnp.int32))
            return drafts, jax.tree_util.tree_map_with_path(
                _pool_or, cache, newc)

        @jax.jit
        def verify_spec(params, cache, toks, pos, pages, wmask):
            """Score the (B, W) window with the target params. NOT
            donated: the commit's lane-select needs the pre-verify dense
            leaves for masked-out slots."""
            return verify_window(params, cache, toks, pos, pages=pages,
                                 write_mask=wmask)

        @partial(jax.jit, donate_argnums=(0, 1))
        def commit_spec(cache, vcache, acc, mask):
            """Fold a verify result into the cache: pool leaves are
            already correct for every slot (masked writes went to
            trash); stacked recurrent leaves (L, W, B, ...) select each
            slot's accepted window offset ``acc``; read-only leaves
            (same ndim, never stacked) stay."""
            def pick(path, o, n):
                if str(getattr(path[-1], "key",
                               path[-1])).endswith("_pages"):
                    return n
                if n.ndim == o.ndim:
                    return o
                sel = n[:, acc, jnp.arange(o.shape[1])]
                m = jnp.reshape(mask, (1, -1) + (1,) * (o.ndim - 2))
                return jnp.where(m, sel, o)

            return jax.tree_util.tree_map_with_path(pick, cache, vcache)

    prefill_chunk = None
    chunk_entry = model.prefill_chunk
    if chunk_entry is not None:
        @partial(jax.jit, donate_argnums=(1,))
        def prefill_chunk(params, cache, toks, pos, pages, slot):
            """Advance slot ``slot`` by one B=1 prompt chunk, written
            straight into the shared page pool. Pool leaves pass through
            whole (the chunk scatters via the page table; other slots'
            pages are untouched); dense (L, B, ...) leaves -- recurrent
            state for hybrid families -- slice the slot's lane, advance
            at B=1 through the chunk, and scatter back. ``slot`` is
            traced, so one compile serves every slot per (C, n_live)
            bucket."""
            def take(path, c):
                if str(getattr(path[-1], "key", path[-1])).endswith("_pages"):
                    return c
                return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)

            sub = jax.tree_util.tree_map_with_path(take, cache)
            logits, new = chunk_entry(params, sub, toks, pos, pages=pages)

            def put(path, c, n):
                if str(getattr(path[-1], "key", path[-1])).endswith("_pages"):
                    return n
                return jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=1)

            return logits, jax.tree_util.tree_map_with_path(put, cache, new)

    fns = {
        "decode_all": decode_all,
        "decode_masked": decode_masked,
        "decode_all_paged": decode_all_paged,
        "decode_masked_paged": decode_masked_paged,
        "draft_spec": draft_spec,
        "verify_spec": verify_spec,
        "commit_spec": commit_spec,
        "install": install,
        "install_paged": install_paged,
        "prefill_chunk": prefill_chunk,
        "prefill": (jax.jit(model.prefill, donate_argnums=(1,))
                    if model.prefill is not None else None),
        "decode_one": jax.jit(decode_step,   # per-token prefill fallback
                              donate_argnums=(1,)),
    }
    _SERVING_FNS[id(model)] = fns
    return fns


class ServeEngine:
    def __init__(self, cfg, store: AdapterStore, n_slots: int = 4,
                 max_len: Optional[int] = None, seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"family {cfg.family!r} has no decode path")
        if spec_k is not None and spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        if spec_k is not None and not paged:
            raise ValueError(
                "spec_k requires paged=True: the draft writes into (and "
                "the verifier overwrites) the slot's shared KV pages")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if prefill_chunk is not None and not paged:
            raise ValueError(
                "prefill_chunk requires paged=True: prompt chunks write "
                "straight into the slot's reserved KV pages")
        self.store = store
        self.n_slots = n_slots
        self.max_len = max_len or cfg.max_seq
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        # families without pageable state serve the dense layout even
        # under paged=True (nothing to page; admission is identical)
        self.paged = bool(paged and self.model.init_paged_cache is not None)
        if spec_k is not None and not self.paged:
            raise ValueError(
                f"family {cfg.family!r} has no pageable state; speculative "
                f"decoding needs a paged KV cache to share between draft "
                f"and verifier")
        if prefill_chunk is not None and not self.paged:
            raise ValueError(
                f"family {cfg.family!r} has no pageable state; chunked "
                f"prefill needs a paged KV cache to write prompt chunks "
                f"into")
        self.spec_k = int(spec_k or 0)
        self.prefill_chunk = int(prefill_chunk or 0)
        self.page_size = page_size
        if self.paged:
            self.slot_pages = -(-self.max_len // page_size)  # per-slot max
            if pool_pages is None:       # default: dense capacity + trash
                pool_pages = n_slots * self.slot_pages + 1
            if pool_pages < 2:
                raise ValueError("pool_pages must be >= 2 (trash + 1)")
            self.pool_pages = pool_pages
            self.cache = self.model.init_paged_cache(
                n_slots, pool_pages, page_size, max_len=self.max_len)
            self._free_pages = list(range(pool_pages - 1, 0, -1))
            self._reserved = 0                     # pages promised, total
            self._slot_alloc: List[List[int]] = [[] for _ in range(n_slots)]
            self._slot_reserve = np.zeros(n_slots, np.int64)
            self._table = np.zeros((n_slots, self.slot_pages), np.int32)
        else:
            self.cache = self.model.init_cache(n_slots, self.max_len)

        self.queue: deque = deque()
        self._next_rid = 0
        self._req: List[Optional[Request]] = [None] * n_slots
        self._active = np.zeros(n_slots, bool)
        self._pos = np.zeros(n_slots, np.int32)
        self._remaining = np.zeros(n_slots, np.int32)
        self._last = np.zeros(n_slots, np.int32)
        self._out: List[List[int]] = [[] for _ in range(n_slots)]
        self._slot_drafted = np.zeros(n_slots, np.int64)
        self._slot_accepted = np.zeros(n_slots, np.int64)
        self._queue_wait = np.zeros(n_slots)
        self._ttft = np.zeros(n_slots)
        self._prefill_slot: Optional[int] = None   # chunked: slot mid-prefill
        self._prefill_off = 0                      # prompt tokens done so far
        self._finished: List[Completion] = []
        self._fns = _serving_fns(self.model)

    # ---- page pool -------------------------------------------------------
    def _pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def _alloc_page(self, slot: int) -> None:
        page = self._free_pages.pop()
        lp = len(self._slot_alloc[slot])
        self._slot_alloc[slot].append(page)
        self._table[slot, lp] = page
        in_use = self.pool_pages - 1 - len(self._free_pages)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           in_use)

    def _release_slot_pages(self, slot: int) -> None:
        self._free_pages.extend(reversed(self._slot_alloc[slot]))
        self._reserved -= int(self._slot_reserve[slot])
        self._slot_reserve[slot] = 0
        self._slot_alloc[slot] = []
        self._table[slot] = 0                      # -> trash page

    # ---- request lifecycle ----------------------------------------------
    def submit(self, req: Request) -> int:
        plen = int(np.asarray(req.prompt).size)
        if plen + req.max_new > self.max_len:
            raise ValueError(f"prompt({plen}) + max_new({req.max_new}) "
                             f"exceeds max_len({self.max_len})")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.paged:
            need = self._pages_needed(plen + req.max_new)
            if need > self.pool_pages - 1:
                raise ValueError(
                    f"request needs {need} pages "
                    f"({plen}+{req.max_new} tokens @ page_size "
                    f"{self.page_size}); pool holds {self.pool_pages - 1}")
        req.rid = self._next_rid
        self._next_rid += 1
        if req.submit_ts is None:
            req.submit_ts = time.perf_counter()
        self.queue.append(req)
        return req.rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.n_slots) if not self._active[i]]

    def _admit(self):
        """Prefill queued requests into free slots (mid-flight). Paged
        mode additionally requires the request's worst-case page count
        to fit in the unreserved pool -- admission is the only gate, so
        growth during decode can never fail. FIFO: a head request that
        does not fit blocks the queue until slots/pages free up.

        Whole-prompt admission blocks every resident decode slot for the
        full prefill (accrued in ``decode_stall_s``); ``prefill_chunk``
        mode delegates to :meth:`_admit_chunked`, which spreads the
        prompt over engine steps."""
        if self.prefill_chunk:
            return self._admit_chunked()
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue[0]
            plen = int(np.asarray(req.prompt).size)
            if self.paged:
                need = self._pages_needed(plen + req.max_new)
                if self._reserved + need > self.pool_pages - 1:
                    return                       # wait for pages to free
            self.queue.popleft()
            params = self.store.materialize(req.user)
            prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
            t0 = time.perf_counter()
            self._queue_wait[slot] = (
                t0 - req.submit_ts if req.submit_ts is not None else 0.0)
            if self.paged:
                self._reserved += need
                self._slot_reserve[slot] = need
                n_prompt_pages = self._pages_needed(plen)
                for _ in range(n_prompt_pages):
                    self._alloc_page(slot)
                fresh_len = n_prompt_pages * self.page_size
            else:
                # bucket the throwaway prefill cache to the next power
                # of two >= plen instead of a full max_len strip: short
                # prompts stop paying max_len HBM and the prefill jit
                # compiles once per bucket (mirroring _live_pages)
                fresh_len = min(1 << max(plen - 1, 0).bit_length(),
                                self.max_len)
            fresh = self.model.init_cache(1, fresh_len)
            if self._fns["prefill"] is not None:
                logits, fresh = self._fns["prefill"](params, fresh,
                                                     jnp.asarray(prompt))
            else:
                toks = jnp.asarray(prompt)
                for t in range(plen):
                    logits, fresh = self._fns["decode_one"](
                        params, fresh, toks[:, t:t + 1], jnp.int32(t))
            if self.paged:
                phys = jnp.asarray(
                    np.asarray(self._slot_alloc[slot], np.int32))
                self.cache = self._fns["install_paged"](
                    self.cache, fresh, phys, slot)
            else:
                self.cache = self._fns["install"](self.cache, fresh, slot)
            jax.block_until_ready(self.cache)
            elapsed = time.perf_counter() - t0
            self.stats.prefill_s += elapsed
            self.stats.decode_stall_s += elapsed * int(self._active.sum())
            self.stats.prefill_tokens += plen
            self.stats.admitted += 1
            self._activate(slot, req,
                           np.asarray(logits[:, -1, :], np.float32)[0], plen)

    def _admit_chunked(self):
        """Chunked admission: at most one prompt in flight, advanced at
        most ``prefill_chunk`` tokens per engine step straight into the
        slot's reserved pages -- no dense B=1 cache, no install scatter,
        and decoding slots keep stepping between chunks. All prompt
        pages are allocated up front (the reservation covers them), so
        every chunk's writes land in live pages. The tail decomposes
        into powers of two (no padding: padded tokens would corrupt the
        dense recurrent state advancing through the chunk)."""
        if self._prefill_slot is None:
            free = self._free_slots()
            if free and self.queue:
                req = self.queue[0]
                plen = int(np.asarray(req.prompt).size)
                need = self._pages_needed(plen + req.max_new)
                if self._reserved + need <= self.pool_pages - 1:
                    self.queue.popleft()
                    slot = free[0]
                    now = time.perf_counter()
                    self._queue_wait[slot] = (
                        now - req.submit_ts if req.submit_ts is not None
                        else 0.0)
                    self._reserved += need
                    self._slot_reserve[slot] = need
                    for _ in range(self._pages_needed(plen)):
                        self._alloc_page(slot)
                    self._req[slot] = req
                    self._prefill_slot = slot
                    self._prefill_off = 0
                    self.stats.admitted += 1
        if self._prefill_slot is None:
            return
        slot = self._prefill_slot
        req = self._req[slot]
        prompt = np.asarray(req.prompt, np.int32)
        plen = prompt.size
        params = self.store.materialize(req.user)
        n_live = 1
        while n_live < len(self._slot_alloc[slot]):
            n_live *= 2
        n_live = min(n_live, self.slot_pages)
        pages = jnp.asarray(self._table[slot:slot + 1, :n_live])
        budget = self.prefill_chunk
        t0 = time.perf_counter()
        done = 0
        logits = None
        while budget > 0 and self._prefill_off < plen:
            c = min(plen - self._prefill_off, budget)
            if c < self.prefill_chunk:   # pow2 tail pieces: bounded shapes
                c = 1 << (c.bit_length() - 1)
            end = self._prefill_off + c
            logits, self.cache = self._fns["prefill_chunk"](
                params, self.cache,
                jnp.asarray(prompt[None, self._prefill_off:end]),
                jnp.asarray([self._prefill_off], np.int32), pages,
                jnp.int32(slot))
            self._prefill_off = end
            budget -= c
            done += c
        jax.block_until_ready(self.cache)
        elapsed = time.perf_counter() - t0
        self.stats.prefill_s += elapsed
        self.stats.decode_stall_s += elapsed * int(self._active.sum())
        self.stats.prefill_tokens += done
        if self._prefill_off < plen:
            return                       # more chunks next step
        self._prefill_slot = None
        self._activate(slot, req,
                       np.asarray(logits[:, -1, :], np.float32)[0], plen)

    def _activate(self, slot: int, req: Request, logits_row: np.ndarray,
                  plen: int):
        """Hand a fully prefilled slot to decode: pick the first token,
        mark the slot active, record time-to-first-token. One key split
        per admission in both admission modes keeps greedy (and the
        per-admission sampling key) bit-identical between them."""
        self.key, sub = jax.random.split(self.key)
        tok = self._pick(req, jax.random.fold_in(sub, slot), logits_row)
        now = time.perf_counter()
        self._ttft[slot] = (now - req.submit_ts
                            if req.submit_ts is not None else 0.0)
        self.stats.queue_wait_s += float(self._queue_wait[slot])
        self.stats.ttft_s += float(self._ttft[slot])
        self._req[slot] = req
        self._active[slot] = True
        self._pos[slot] = plen
        self._remaining[slot] = req.max_new - 1
        self._last[slot] = tok
        self._out[slot] = [tok]
        self._slot_drafted[slot] = 0
        self._slot_accepted[slot] = 0
        self.stats.peak_active_slots = max(self.stats.peak_active_slots,
                                           int(self._active.sum()))
        if self._remaining[slot] == 0:
            self._finish(slot)

    def _pick(self, req: Request, key, logits_row: np.ndarray) -> int:
        if req.greedy:
            return int(logits_row.argmax())
        tok = sampling.sample_topk(key[None], jnp.asarray(logits_row)[None],
                                   req.topk or logits_row.size,
                                   req.temperature)
        return int(np.asarray(tok)[0])

    def _finish(self, slot: int):
        req = self._req[slot]
        drafted = int(self._slot_drafted[slot])
        self._finished.append(Completion(
            rid=req.rid, user=req.user, prompt=np.asarray(req.prompt),
            tokens=np.asarray(self._out[slot], np.int32),
            accept_rate=(int(self._slot_accepted[slot]) / drafted
                         if drafted else None),
            queue_wait_s=float(self._queue_wait[slot]),
            ttft_s=float(self._ttft[slot])))
        self._active[slot] = False
        self._req[slot] = None
        if self.paged:
            self._release_slot_pages(slot)
        self.stats.finished += 1

    # ---- decode ---------------------------------------------------------
    def _live_pages(self, cover: np.ndarray):
        """Grow page tables to cover this step's highest write position
        per slot (plain decode: ``pos``; speculative rounds: ``pos +
        draft_len``, which the admission reservation still covers), then
        return the (n_slots, n_live) table slice spanning every live
        page -- n_live bucketed to powers of two so the decode dispatch
        compiles once per bucket, not once per length."""
        for slot in np.flatnonzero(self._active):
            while (len(self._slot_alloc[slot])
                   <= cover[slot] // self.page_size):
                self._alloc_page(slot)          # reservation guarantees one
        maxp = 1 + int(cover[self._active].max()) // self.page_size
        n_live = 1
        while n_live < maxp:
            n_live *= 2
        n_live = min(n_live, self.slot_pages)
        return jnp.asarray(self._table[:, :n_live])

    def _spec_step(self):
        """One speculative round: base drafts up to ``spec_k`` tokens per
        slot into the shared pages, target verifies the whole window in
        one batched call, the longest accepted prefix (plus the target's
        correction/bonus token) is committed. Greedy slots accept by
        exact argmax prefix match -- output is bit-identical to the
        plain engine; sampled slots run speculative rejection sampling
        (:func:`repro.serve.sampling.spec_accept`)."""
        self._admit()
        if not self._active.any():
            return
        t0 = time.perf_counter()
        k = self.spec_k
        act = self._active.copy()
        d = np.where(act, np.minimum(k, self._remaining), 0).astype(np.int32)
        pos_np = np.minimum(self._pos, self.max_len - 1)
        pages = self._live_pages(pos_np + d)
        drafts, self.cache = self._fns["draft_spec"](
            self.store.materialize(None), self.cache,
            jnp.asarray(self._last), jnp.asarray(pos_np), pages,
            jnp.asarray(d), k)
        drafts = np.asarray(drafts)                     # (k, n_slots)
        win = np.concatenate([self._last.reshape(-1, 1), drafts.T],
                             axis=1).astype(np.int32)   # (n_slots, k+1)
        win_len = d + 1
        # snapshot slot->user before any commit can finish (and null) a
        # slot's request mid-round; masks stay disjoint across users
        slot_user = {i: self._req[i].user for i in np.flatnonzero(act)}
        users = set(slot_user.values())
        if any(not self._req[i].greedy for i in np.flatnonzero(act)):
            self.key, keys = sampling.step_keys(self.key, self.n_slots)
            keys = np.asarray(keys)
        n_committed = 0
        for u in users:
            mask = np.array([i in slot_user and slot_user[i] == u
                             for i in range(self.n_slots)])
            wmask = mask[:, None] & (np.arange(k + 1)[None, :]
                                     < win_len[:, None])
            params = self.store.materialize(u)
            lg, vstate = self._fns["verify_spec"](
                params, self.cache, jnp.asarray(win), jnp.asarray(pos_np),
                pages, jnp.asarray(wmask))
            lg = np.asarray(lg, np.float32)             # (n_slots, k+1, V)
            acc = np.zeros(self.n_slots, np.int32)
            committed: Dict[int, List[int]] = {}
            for slot in np.flatnonzero(mask):
                req = self._req[slot]
                ds = int(d[slot])
                rem = int(self._remaining[slot])        # >= 1 while active
                if req.greedy:
                    tgt = lg[slot, :ds + 1].argmax(axis=1).astype(np.int32)
                    a = 0
                    while a < ds and drafts[a, slot] == tgt[a]:
                        a += 1
                    toks = tgt[:min(a + 1, rem)].tolist()
                else:
                    n_acc, nxt = sampling.spec_accept(
                        jnp.asarray(keys[slot]),
                        jnp.asarray(drafts[:ds, slot]),
                        jnp.asarray(lg[slot, :ds + 1]),
                        req.topk or self.cfg.vocab, req.temperature)
                    a = int(n_acc)
                    toks = (drafts[:a, slot].tolist()
                            + [int(np.asarray(nxt))])[:min(a + 1, rem)]
                committed[slot] = toks
                acc[slot] = len(toks) - 1      # state after consuming
                #                                window offsets [0, len)
                self._slot_drafted[slot] += ds
                self._slot_accepted[slot] += min(a, len(toks))
                self.stats.spec_drafted += ds
                self.stats.spec_accepted += min(a, len(toks))
            self.cache = self._fns["commit_spec"](
                self.cache, vstate, jnp.asarray(acc), jnp.asarray(mask))
            for slot, toks in committed.items():
                self._out[slot].extend(toks)
                self._last[slot] = toks[-1]
                self._pos[slot] += len(toks)
                self._remaining[slot] -= len(toks)
                n_committed += len(toks)
                if (self._remaining[slot] == 0
                        or self._pos[slot] >= self.max_len - 1):
                    self._finish(slot)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_tokens += n_committed
        self.stats.decode_steps += 1

    def step(self):
        """Admit whatever fits, then advance every active slot one token
        (or one speculative window when ``spec_k`` is set)."""
        if self.spec_k:
            return self._spec_step()
        self._admit()
        if not self._active.any():
            return
        t0 = time.perf_counter()
        toks = jnp.asarray(self._last.reshape(self.n_slots, 1))
        pos_np = np.minimum(self._pos, self.max_len - 1)
        pos = jnp.asarray(pos_np)
        pages = self._live_pages(pos_np) if self.paged else None
        users = {self._req[i].user for i in range(self.n_slots)
                 if self._active[i]}
        merged = np.zeros((self.n_slots, self.cfg.vocab), np.float32)
        # while a chunked prefill is in flight its slot must not see
        # unmasked decode writes: the slot's table row points at real
        # pages (not trash) and its dense recurrent lane is mid-advance,
        # so the all-slots fast path would corrupt both
        if len(users) == 1 and self._prefill_slot is None:
            params = self.store.materialize(next(iter(users)))
            if self.paged:
                lg, self.cache = self._fns["decode_all_paged"](
                    params, self.cache, toks, pos, pages)
            else:
                lg, self.cache = self._fns["decode_all"](
                    params, self.cache, toks, pos)
            merged[:] = np.asarray(lg[:, -1, :], np.float32)
        else:
            for u in users:
                mask = np.array([self._active[i]
                                 and self._req[i].user == u
                                 for i in range(self.n_slots)])
                params = self.store.materialize(u)
                if self.paged:
                    lg, self.cache = self._fns["decode_masked_paged"](
                        params, self.cache, toks, pos, pages,
                        jnp.asarray(mask))
                else:
                    lg, self.cache = self._fns["decode_masked"](
                        params, self.cache, toks, pos, jnp.asarray(mask))
                merged[mask] = np.asarray(lg[:, -1, :], np.float32)[mask]

        n_active = int(self._active.sum())
        picked: Dict[int, int] = {}
        groups: Dict[tuple, List[int]] = {}   # (topk, temp) -> slots
        for slot in np.flatnonzero(self._active):
            req = self._req[slot]
            if req.greedy:
                picked[slot] = int(merged[slot].argmax())
            else:
                groups.setdefault((req.topk or self.cfg.vocab,
                                   req.temperature), []).append(int(slot))
        if groups:          # key split only when someone actually samples
            self.key, keys = sampling.step_keys(self.key, self.n_slots)
            keys = np.asarray(keys)
        for (k, temp), slots in groups.items():   # one dispatch per combo
            toks_s = sampling.sample_topk(keys[np.asarray(slots)],
                                          jnp.asarray(merged[slots]), k, temp)
            picked.update(zip(slots, np.asarray(toks_s).tolist()))
        for slot, tok in picked.items():
            self._out[slot].append(tok)
            self._last[slot] = tok
            self._pos[slot] += 1
            self._remaining[slot] -= 1
            if (self._remaining[slot] == 0
                    or self._pos[slot] >= self.max_len - 1):
                self._finish(slot)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_tokens += n_active
        self.stats.decode_steps += 1

    def drain_finished(self) -> List[Completion]:
        out, self._finished = self._finished, []
        return out

    def run(self) -> List[Completion]:
        """Serve until queue and slots are empty; completions rid-sorted."""
        out: List[Completion] = []
        while (self.queue or self._active.any()
               or self._prefill_slot is not None):
            self.step()
            out.extend(self.drain_finished())
        return sorted(out, key=lambda c: c.rid)
