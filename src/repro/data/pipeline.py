"""Host-side data pipeline: background prefetch + sharded device_put.

At multi-host scale each process feeds only its addressable shard of the
global batch; ``jax.make_array_from_process_local_data`` handles the
host->device scatter. On single-process meshes ``jax.device_put`` with a
NamedSharding does the same thing.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np


class DataPipeline:
    def __init__(self, source: Iterator[Any], sharding=None,
                 prefetch: int = 2):
        self._source = source
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._dead: Optional[str] = None   # why __next__ can't proceed
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch,
            self._sharding)

    def _put(self, item) -> bool:
        """Stop-aware put: a plain blocking ``put`` on a full queue
        deadlocks shutdown (the consumer is gone, nothing ever drains),
        so block in short slices and re-check the stop flag between
        them. Returns False when stopped without enqueueing."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for batch in self._source:
                if self._stop.is_set():
                    return
                if not self._put(self._place(batch)):
                    return
        except Exception as e:  # surface errors on the consumer side
            self._put(e)
            return
        self._put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        if self._dead == "exhausted":
            raise StopIteration            # iterator protocol: stay done
        if self._dead is not None:
            # after a worker error or close() the queue never refills --
            # a bare q.get() would hang forever
            raise RuntimeError(f"DataPipeline is closed ({self._dead})")
        item = self._q.get()
        if isinstance(item, StopIteration):
            self._dead = "exhausted"
            raise item
        if isinstance(item, Exception):
            self._dead = f"worker raised {type(item).__name__}"
            raise item
        return item

    def close(self):
        """Idempotent shutdown: stop the worker (a stop-aware put never
        wedges on a full queue), drain whatever it enqueued, and join so
        no producer thread outlives the pipeline."""
        self._stop.set()
        if self._dead is None:
            self._dead = "close() called"
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
