"""Recompute roofline fields of dry-run JSONs from their saved .hlo.gz.

The dry-run persists the SPMD-partitioned HLO next to each record, so
analyzer improvements (loop-aware trip counting, carried-buffer HBM
charging) can be re-applied offline without recompiling:

  PYTHONPATH=src python -m benchmarks.reanalyze experiments/dryrun \
      experiments/dryrun_baseline experiments/perf
"""

from __future__ import annotations

import gzip
import json
import os
import sys

from repro.roofline.analysis import roofline_terms


def reanalyze_dir(d: str) -> int:
    n = 0
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        path = os.path.join(d, f)
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        hpath = path[:-5] + ".hlo.gz"
        if not os.path.exists(hpath):
            continue
        hlo = gzip.open(hpath, "rt").read()
        from repro.configs import get_config
        try:
            cfg = get_config(rec["arch"])
        except KeyError:
            cfg = None
        import numpy as np
        n_chips = int(np.prod(rec["mesh"]["shape"]))
        mode = ("train" if rec.get("optimizer") in ("mezo", "mezo-parallel")
                else ("train-adam" if rec.get("optimizer") == "adam"
                      else rec["mode"]))
        rec["roofline"] = roofline_terms(
            rec.get("cost_analysis", {}), hlo, n_chips, cfg=cfg,
            n_tokens=rec["n_tokens"], mode=mode)
        from repro.roofline.hlo import collective_bytes
        rec["collectives"] = collective_bytes(hlo)
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        n += 1
    return n


def main():
    dirs = sys.argv[1:] or ["experiments/dryrun"]
    for d in dirs:
        if os.path.isdir(d):
            print(f"[reanalyze] {d}: {reanalyze_dir(d)} records updated")


if __name__ == "__main__":
    main()
