"""Expert-parallel (shard_map) MoE == auto-sharded MoE, on 8 fake devices.

Runs in a subprocess because the device count must be fixed before jax
initializes (the main test process keeps the default 1 device).
"""

import os
import subprocess
import sys

import jax
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.models import moe as MoE
from repro.models.config import ModelConfig

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="moe", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=4, d_ff=64, vocab=64, n_experts=8, topk=2,
                  expert_dff=48, capacity_factor=8.0, dtype="float32")
key = jax.random.PRNGKey(0)
p = MoE.moe_init(cfg, key)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model))

want, aux_w = MoE.moe_apply(cfg, p, x)        # single-device reference

with jax.set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = {"router": jax.device_put(p["router"], NamedSharding(mesh, P())),
          "w_in": jax.device_put(p["w_in"],
                                 NamedSharding(mesh, P("model", None, None))),
          "w_out": jax.device_put(p["w_out"],
                                  NamedSharding(mesh, P("model", None, None)))}
    got, aux_g = jax.jit(lambda pp, xx: MoE.moe_apply_ep(cfg, pp, xx))(ps, xs)

np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aux_g), float(aux_w), rtol=1e-4)

# collective check: EP path must not all-reduce expert buffers
from repro.roofline.hlo import analyze
with jax.set_mesh(mesh):
    lowered = jax.jit(lambda pp, xx: MoE.moe_apply_ep(cfg, pp, xx)[0]).lower(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                    sharding=l.sharding), ps),
        jax.ShapeDtypeStruct(xs.shape, xs.dtype, sharding=xs.sharding))
    a_ep = analyze(lowered.compile().as_text())
    lowered2 = jax.jit(lambda pp, xx: MoE.moe_apply(cfg, pp, xx)[0]).lower(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                    sharding=l.sharding), ps),
        jax.ShapeDtypeStruct(xs.shape, xs.dtype, sharding=xs.sharding))
    a_auto = analyze(lowered2.compile().as_text())
print("EP coll:", a_ep["collective_bytes"], "AUTO coll:",
      a_auto["collective_bytes"])
assert a_ep["collective_bytes"] <= a_auto["collective_bytes"]
print("EP_MOE_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")),
    reason="needs jax explicit-sharding API (jax.sharding.AxisType / "
           "jax.set_mesh, jax >= 0.6)")
def test_ep_moe_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=600)
    assert "EP_MOE_OK" in r.stdout, r.stdout + r.stderr
