"""Multi-tenant ZO training (the trainer-side twin of repro.serve)."""

from repro.train.engine import (JobResult, TrainEngine,  # noqa: F401
                                TrainJob, derive_user_seed)
