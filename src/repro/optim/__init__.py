"""Derivative-based baselines (Adam, SGD) + the int8 quantized-base
runtime (``optim/quant.py``)."""

from repro.optim.adam import (AdamConfig, AdamState, adam_init, adam_update,
                              grad_train_step, sgd_train_step)
from repro.optim.quant import (QUANT_MODES, QuantizedLeaf, check_quant_mode,
                               dequantize_tree, is_quantized, quantize_leaf,
                               quantize_tree, tree_is_quantized, with_delta)

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "grad_train_step", "sgd_train_step", "QUANT_MODES",
           "QuantizedLeaf", "check_quant_mode", "dequantize_tree",
           "is_quantized", "quantize_leaf", "quantize_tree",
           "tree_is_quantized", "with_delta"]
