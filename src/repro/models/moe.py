"""Top-k Mixture-of-Experts with sort-based capacity dispatch (EP).

Design notes (vs. the GShard one-hot dispatch einsum): the one-hot
dispatch tensor is (tokens, experts, capacity) which for kimi-k2
(T_local=64k, E=384) is tens of GB per device. We instead sort the
(token, expert) assignment list by expert id and scatter rows into an
(E, C, D) buffer -- O(T*k*D) memory, the true lower bound for top-k.

Sharding: the token axis is data-sharded; the expert axis of the buffers
and of the expert weights is model-sharded (expert parallelism). The
token->expert redistribution lowers to an all-to-all under SPMD.

Overflowing tokens beyond capacity are dropped (standard capacity-factor
semantics); their combine weight is zero so the residual path carries
them unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import maybe_shard


def moe_init(cfg, key, d_model=None):
    d = d_model or cfg.d_model
    e, f = cfg.n_experts, cfg.expert_dff or cfg.d_ff
    ks = jax.random.split(key, 4)
    gated = cfg.act in ("swiglu", "geglu")
    # gated: interleaved (E, D, F, 2) so up/gate pairs stay on one shard
    # under any F-dim sharding (same rationale as layers.mlp_init)
    win_shape = (e, d, f, 2) if gated else (e, d, f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02),
        "w_in": (jax.random.normal(ks[1], win_shape, jnp.float32)
                 * 0.02).astype(L._dt(cfg)),
        "w_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                  * 0.02 / max(cfg.n_layers, 1) ** 0.5).astype(L._dt(cfg)),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(
            cfg, ks[3], d_ff=cfg.n_shared_experts * f, d_model=d)
    return p


def _expert_ffn(cfg, w_in, w_out, x):
    """x: (E, C, D) -> (E, C, D), per-expert weights stacked on dim 0."""
    if cfg.act in ("swiglu", "geglu"):
        h = jnp.einsum("ecd,edfg->ecfg", x, w_in)
        u, g = h[..., 0], h[..., 1]
        h = u * (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g))
    elif cfg.act == "gelu":
        h = jnp.einsum("ecd,edf->ecf", x, w_in)
        h = jax.nn.gelu(h)
    else:
        h = jnp.einsum("ecd,edf->ecf", x, w_in)
        h = jax.nn.relu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.topk * cfg.capacity_factor / cfg.n_experts)
    return max(8, c)


def _ambient_mesh():
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if am is None or getattr(am, "empty", True):
        return None
    return am


def moe_apply_ep(cfg, p, x):
    """Expert-parallel MoE via shard_map over the ``model`` axis.

    Beyond-paper optimization (EXPERIMENTS.md §Perf): the jit-auto version
    below scatters into a *globally-shaped* (E, C, D) buffer, which XLA
    partitions with a full-buffer all-reduce per layer (~GBs/chip). Here
    each model shard owns E/model_size experts, selects its own tokens
    from the (TP-replicated) activations locally, and the only collective
    is the psum of the combined (T, D) output -- the same AR Megatron
    pays for an MLP block. Bit-identical results to moe_apply (same
    router, same capacity semantics, per-shard capacity C/shards).
    """
    am = _ambient_mesh()
    mesh_axes = set(am.axis_names or ()) if am is not None else set()
    if "model" not in mesh_axes:
        return moe_apply(cfg, p, x)
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    n_model = sizes["model"]
    if cfg.n_experts % n_model:
        return moe_apply(cfg, p, x)

    b, s, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    prod = 1
    chosen = []
    for a in batch_axes:
        if b % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    bspec = tuple(chosen) if len(chosen) > 1 else (
        chosen[0] if chosen else None)
    e_local = cfg.n_experts // n_model
    t_local = (b // prod) * s
    c = capacity(t_local, cfg)

    fsdp = cfg.fsdp_params and "data" in mesh_axes
    gated = cfg.act in ("swiglu", "geglu")
    if fsdp and gated and b * s <= 8192:
        # decode-sized token counts: moving 2 TB of expert weights over
        # ICI for a few thousand tokens is backwards -- keep the weights
        # stationary, replicate the (tiny) tokens instead
        return _moe_ep_weights_stationary(cfg, p, x, am, sizes)
    # fold the always-on shared expert into the same psum as the routed
    # experts: its w_out partial sum rides the existing AR instead of
    # paying a second x-shaped all-reduce per MoE layer
    fold_shared = bool(cfg.n_shared_experts) and gated and "shared" in p

    def inner(xl, router, w_in, w_out, *shared_w):
        bl, sl, dl = xl.shape
        t = bl * sl
        xf = xl.reshape(t, dl)
        me = jax.lax.axis_index("model")
        if fsdp:
            # ZeRO-3 style: expert weights stored F-sharded over `data`;
            # gather this layer's local experts just-in-time (transient,
            # freed after the einsums -- the storage stays 2-D sharded)
            w_in = jax.lax.all_gather(w_in, "data", axis=2, tiled=True)
            w_out = jax.lax.all_gather(w_out, "data", axis=1, tiled=True)
            # (F is axis 2 for both the gated (E,D,F,2) and flat (E,D,F)
            # layouts, so the gather axis is layout-independent)
        logits = (xf.astype(jnp.float32) @ router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, cfg.topk)
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
        density = jnp.mean(
            jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32), 0)
        if chosen:  # global token mean, matching the auto-sharded path
            density = jax.lax.pmean(density, tuple(chosen))
            probs_mean = jax.lax.pmean(probs.mean(0), tuple(chosen))
        else:
            probs_mean = probs.mean(0)
        aux = cfg.n_experts * jnp.mean(probs_mean * density)

        lo = me * e_local
        flat_e = idx.reshape(-1) - lo
        flat_t = jnp.repeat(jnp.arange(t), cfg.topk)
        flat_g = gate.reshape(-1)
        mine = (flat_e >= 0) & (flat_e < e_local)
        sort_key = jnp.where(mine, flat_e, e_local)   # sentinel tail
        order = jnp.argsort(sort_key, stable=True)
        sk, st, sg, sm = (sort_key[order], flat_t[order], flat_g[order],
                          mine[order])
        sec = jnp.clip(sk, 0, e_local - 1)
        starts = jnp.searchsorted(sk, jnp.arange(e_local))
        pos = jnp.arange(t * cfg.topk) - starts[sec]
        keep = sm & (pos < c)
        slot = jnp.where(keep, sec * c + pos, 0)
        buf = jnp.zeros((e_local * c, dl), xl.dtype)
        rows = jnp.where(keep[:, None], xf[st], 0).astype(xl.dtype)
        buf = buf.at[slot].add(rows).reshape(e_local, c, dl)
        yexp = _expert_ffn(cfg, w_in, w_out, buf).reshape(e_local * c, dl)
        contrib = yexp[slot] * (sg * keep).astype(xl.dtype)[:, None]
        out = jax.ops.segment_sum(contrib, st, num_segments=t)
        if fold_shared:
            sw_in, sw_out = shared_w
            h = jnp.einsum("td,dfg->tfg", xf, sw_in)
            act = (jax.nn.silu(h[..., 1]) if cfg.act == "swiglu"
                   else jax.nn.gelu(h[..., 1]))
            out = out + (h[..., 0] * act) @ sw_out
        # psum in the activation dtype (bf16): each partial is already a
        # <= topk-expert sum; halves both the combine HBM traffic and the
        # AR wire bytes vs an f32 reduction (EXPERIMENTS.md Sec Perf it.3)
        out = jax.lax.psum(out, "model")
        return out.reshape(bl, sl, dl), aux

    P_ = jax.sharding.PartitionSpec
    win_rest = (None,) if gated else ()
    win_spec = P_("model", None, "data" if fsdp else None, *win_rest)
    wout_spec = P_("model", "data", None) if fsdp else P_("model", None, None)
    args = [x, p["router"], p["w_in"], p["w_out"]]
    in_specs = [P_(bspec, None, None), P_(), win_spec, wout_spec]
    if fold_shared:
        args += [p["shared"]["w_in"]["w"], p["shared"]["w_out"]["w"]]
        in_specs += [P_(None, "model", None), P_("model", None)]
    out, aux = jax.shard_map(
        inner, mesh=am,
        in_specs=tuple(in_specs),
        out_specs=(P_(bspec, None, None), P_()),
        check_vma=False,
    )(*args)

    if cfg.n_shared_experts and not fold_shared:
        out = out + L.mlp_apply(cfg, p["shared"], x)
    return out, aux


def _moe_ep_weights_stationary(cfg, p, x, am, sizes):
    """Inference-MoE dispatch for tiny token counts (decode).

    Tokens are all-gathered across the batch axes (KBs), every
    (model, data) shard computes its experts' F-slice partials in place,
    and one psum over (model, data) returns the combined output -- zero
    weight movement. The train path (t >> weight bytes) instead gathers
    weights (see moe_apply_ep).
    """
    b, s, d = x.shape
    mesh_axes = set(am.axis_names or ())
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    prod = 1
    chosen = []
    for a in batch_axes:
        if b % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    bspec = tuple(chosen) if len(chosen) > 1 else (
        chosen[0] if chosen else None)
    n_model = sizes["model"]
    e_local = cfg.n_experts // n_model
    t_all = b * s
    c = capacity(t_all, cfg)
    P_ = jax.sharding.PartitionSpec

    def inner(xl, router, w_in, w_out, *shared_w):
        if chosen:
            xl = jax.lax.all_gather(xl, tuple(chosen), axis=0, tiled=True)
        bl, sl, dl = xl.shape
        t = bl * sl
        xf = xl.reshape(t, dl)
        me = jax.lax.axis_index("model")
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, cfg.topk)
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
        density = jnp.mean(
            jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32), 0)
        aux = cfg.n_experts * jnp.mean(probs.mean(0) * density)

        lo = me * e_local
        flat_e = idx.reshape(-1) - lo
        flat_t = jnp.repeat(jnp.arange(t), cfg.topk)
        flat_g = gate.reshape(-1)
        mine = (flat_e >= 0) & (flat_e < e_local)
        sort_key = jnp.where(mine, flat_e, e_local)
        order = jnp.argsort(sort_key, stable=True)
        sk, st, sg, sm = (sort_key[order], flat_t[order], flat_g[order],
                          mine[order])
        sec = jnp.clip(sk, 0, e_local - 1)
        starts = jnp.searchsorted(sk, jnp.arange(e_local))
        pos = jnp.arange(t * cfg.topk) - starts[sec]
        keep = sm & (pos < c)
        slot = jnp.where(keep, sec * c + pos, 0)
        buf = jnp.zeros((e_local * c, dl), xl.dtype)
        rows = jnp.where(keep[:, None], xf[st], 0).astype(xl.dtype)
        buf = buf.at[slot].add(rows).reshape(e_local, c, dl)
        # expert FFN on the LOCAL F-slice: (E_l, D, F_l, 2) x (E_l, F_l, D)
        h = jnp.einsum("ecd,edfg->ecfg", buf, w_in)
        act = (jax.nn.silu(h[..., 1]) if cfg.act == "swiglu"
               else jax.nn.gelu(h[..., 1]))
        yexp = jnp.einsum("ecf,efd->ecd", h[..., 0] * act,
                          w_out).reshape(e_local * c, dl)
        contrib = yexp[slot] * (sg * keep).astype(xl.dtype)[:, None]
        out = jax.ops.segment_sum(contrib, st, num_segments=t)
        if shared_w:
            sw_in, sw_out = shared_w
            hs = jnp.einsum("td,dfg->tfg", xf, sw_in)
            acts = (jax.nn.silu(hs[..., 1]) if cfg.act == "swiglu"
                    else jax.nn.gelu(hs[..., 1]))
            out = out + (hs[..., 0] * acts) @ sw_out
        out = jax.lax.psum(out, ("model",) + tuple(chosen))
        out = out.reshape(bl, sl, dl)
        if chosen:
            sizes_c = [sizes[a] for a in chosen]
            idx_flat = jnp.int32(0)
            for a, sz in zip(chosen, sizes_c):
                idx_flat = idx_flat * sz + jax.lax.axis_index(a)
            out = jax.lax.dynamic_slice_in_dim(
                out, idx_flat * (bl // int(np.prod(sizes_c))),
                bl // int(np.prod(sizes_c)), axis=0)
        return out, aux

    fold_shared = bool(cfg.n_shared_experts) and "shared" in p
    args = [x, p["router"], p["w_in"], p["w_out"]]
    in_specs = [P_(bspec, None, None), P_(),
                P_("model", None, "data", None),
                P_("model", "data", None)]
    if fold_shared:
        args += [p["shared"]["w_in"]["w"], p["shared"]["w_out"]["w"]]
        in_specs += [P_(None, "model", None), P_("model", None)]
    out, aux = jax.shard_map(
        inner, mesh=am, in_specs=tuple(in_specs),
        out_specs=(P_(bspec, None, None), P_()),
        check_vma=False,
    )(*args)
    return out, aux


def moe_apply(cfg, p, x, rng_aux=None):
    """x: (B, S, D) -> (B, S, D). Returns (out, aux_loss)."""
    b, s, d = x.shape
    tt = b * s
    e, k = cfg.n_experts, cfg.topk
    c = capacity(tt, cfg)
    xf = x.reshape(tt, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), 0)
    aux = e * jnp.mean(probs.mean(0) * density)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = idx.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(tt), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e))              # (E,)
    pos = jnp.arange(tt * k) - starts[se]
    keep = pos < c
    slot = se * c + jnp.where(keep, pos, 0)

    buf = jnp.zeros((e * c, d), x.dtype)
    rows = jnp.where(keep[:, None], xf[st], 0).astype(x.dtype)
    buf = buf.at[slot].add(rows)
    # expert-parallel: buffers live expert-sharded over the model axis;
    # the scatter above is the token->expert all-to-all under SPMD
    buf = maybe_shard(buf.reshape(e, c, d), "model", None, None)

    yexp = _expert_ffn(cfg, p["w_in"], p["w_out"], buf)
    yexp = maybe_shard(yexp, "model", None, None).reshape(e * c, d)

    # ---- combine --------------------------------------------------------
    contrib = yexp[slot] * (sg * keep).astype(x.dtype)[:, None]
    out = jax.ops.segment_sum(contrib, st, num_segments=tt)
    out = out.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + L.mlp_apply(cfg, p["shared"], x)
    return out, aux
