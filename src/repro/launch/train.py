"""Training launcher (the paper's end-to-end flow, cluster-shaped).

On real hardware this runs under ``jax.distributed.initialize`` with the
production mesh; on this CPU container it runs reduced configs single-
device (examples/quickstart.py) -- same code path, smaller shapes.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --optimizer mezo --steps 200 --batch 8 --seq 64

The training strategy is resolved from the core engine's registry:
``--optimizer`` names a registered strategy (or ``adam``), while
``--estimator`` / ``--update`` compose any pairing from the
estimator×update matrix directly, e.g.

  ... --estimator fused --update momentum --momentum 0.9
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core.engine import (estimator_names, strategy_names,
                               update_rule_names)
from repro.core.mezo import MezoConfig
from repro.data.synthetic import lm_batches, sst2_batches
from repro.optim.adam import AdamConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def make_trainer(args) -> Trainer:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.seq and cfg.family != "encoder":
        cfg = dataclasses.replace(cfg, max_seq=max(cfg.max_seq, args.seq))

    if cfg.n_classes:
        batches = sst2_batches(args.batch, args.seq or 64, cfg.vocab,
                               seed=args.seed)
    else:
        batches = lm_batches(args.batch, args.seq or 64, cfg.vocab,
                             seed=args.seed)
        if cfg.family == "encdec" or cfg.num_patches:
            base = batches

            def with_frontend_stub(it=base):
                rng = np.random.default_rng(args.seed + 7)
                for b in it:
                    if cfg.family == "encdec":
                        b["enc_embeds"] = rng.standard_normal(
                            (args.batch, cfg.enc_len, cfg.d_model),
                            dtype=np.float32)
                    if cfg.num_patches:
                        b["patch_embeds"] = rng.standard_normal(
                            (args.batch, cfg.num_patches, cfg.d_model),
                            dtype=np.float32)
                    yield b
            batches = with_frontend_stub()

    tcfg = TrainerConfig(
        optimizer=args.optimizer,
        estimator=args.estimator, update=args.update,
        quant=args.quant,
        mezo=MezoConfig(eps=args.eps, lr=args.lr,
                        n_directions=args.directions, dist=args.zo_dist,
                        use_kernel=args.use_kernel,
                        momentum=args.momentum,
                        momentum_window=args.momentum_window,
                        weight_decay=args.weight_decay),
        adam=AdamConfig(lr=args.adam_lr),
        n_steps=args.steps, seed=args.seed, ckpt_dir=args.ckpt_dir,
        snapshot_every=args.snapshot_every, log_every=args.log_every,
        straggler_redundancy=args.straggler_redundancy)
    return Trainer(cfg, tcfg, batches)


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-1.3b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--optimizer", default="mezo",
                    choices=strategy_names() + ["adam"],
                    help="registered strategy name, or adam (gradient "
                         "baseline)")
    ap.add_argument("--estimator", default=None,
                    choices=estimator_names(),
                    help="direction evaluator; with --update, composes any "
                         "estimator×update pairing (overrides --optimizer)")
    ap.add_argument("--update", default=None, choices=update_rule_names(),
                    help="update rule applied to the (seed, gs) estimate")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--adam-lr", type=float, default=1e-4)
    ap.add_argument("--directions", type=int, default=1)
    ap.add_argument("--momentum", type=float, default=0.9,
                    help="ZO momentum beta (momentum update rule only)")
    ap.add_argument("--momentum-window", type=int, default=8,
                    help="steps of (seed, gs) history the truncated "
                         "seed-replay momentum keeps")
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--zo-dist", default="rademacher",
                    choices=["rademacher", "gaussian"])
    ap.add_argument("--quant", default="none",
                    help="base-weight quantization mode (none | int8): "
                         "int8 freezes the base as int8 + per-channel "
                         "scales with dequant fused into the perturbed-"
                         "forward kernels; the ZO update stream lands in "
                         "per-leaf f32 deltas. Validated by the trainer "
                         "(unknown modes raise with the supported list)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route MXU-aligned leaves/projections through the "
                         "Pallas ZO kernels (zo_add, and zo_matmul for "
                         "mezo-fused). TPU-oriented: on CPU the kernels run "
                         "in slow interpret mode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-redundancy", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    return ap


def main():
    args = build_argparser().parse_args()

    tr = make_trainer(args)
    params = tr.train()
    del params
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump({"arch": args.arch, "optimizer": args.optimizer,
                       "losses": tr.losses}, f)
    print(f"[train] done: loss {tr.losses[0]:.4f} -> {tr.losses[-1]:.4f} "
          f"({len(tr.losses)} steps)")


if __name__ == "__main__":
    main()
