"""Async direction service: fault injection, elastic resizes, and the
bit-replayability contract -- plus regression pins for the fleet-path
bugfix sweep (pipeline shutdown, replay-log conflicts, straggler-policy
validation, stranded-device warning)."""

import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.replay_log import ReplayLog, replay_into
from repro.configs import get_config
from repro.core.engine import MezoConfig, STALE_SGD, SGD
from repro.data.pipeline import DataPipeline
from repro.runtime.elastic import mesh_shape_for
from repro.runtime.fleet import (FaultSpec, FleetCoordinator, FleetSim,
                                 WorkerSpec, get_grade, lease_latency_s)
from repro.runtime.stragglers import StragglerPolicy

CFG = get_config("gemma-2b").reduced()
MZ = MezoConfig(lr=1e-3, n_directions=2, staleness_decay=0.95)


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32)))),
        a, b)))


# ---------------------------------------------------------------------------
# the acceptance scenario: everything at once


def test_faulty_elastic_run_replays_bit_exact(tmp_path):
    """Stragglers + duplicate deliveries + one mid-run join + one leave:
    the staleness-bearing log alone reconstructs live params at atol=0."""
    log = str(tmp_path / "fleet.jsonl")
    workers = [
        WorkerSpec("flagship", FaultSpec(jitter=0.2, duplicate_every=2)),
        WorkerSpec("flagship", FaultSpec(jitter=0.2)),
        WorkerSpec("flagship", FaultSpec(jitter=0.2)),
        WorkerSpec("flagship", FaultSpec(latency_scale=5.0)),  # straggler
    ]
    sim = FleetSim(CFG, workers, total_steps=20, mezo_cfg=MZ, batch=2,
                   seq=16, seed=0, log_path=log,
                   step_events=[(5, "join", WorkerSpec("flagship")),
                                (10, "leave", 2)])
    rep = sim.run()

    assert rep.applied == 20
    assert rep.resizes == 2                      # one join, one leave
    assert rep.dropped > 0                       # duplicates discarded
    assert max(rep.staleness) > 0                # genuinely async
    assert sorted(r["step"] for r in rep.records) == list(range(20))
    assert [r["step"] for r in rep.records] != list(range(20)), \
        "applies should arrive out of step order under async delivery"

    # crash recovery: theta_0 + the log is the whole checkpoint
    recs = ReplayLog.read(log)
    p0 = sim.model.init(jax.random.PRNGKey(0))
    replayed, last = replay_into(p0, recs, MZ)
    assert _max_diff(replayed, rep.params) == 0.0
    assert last == rep.records[-1]["step"]


def test_worker_death_mid_lease_reissues(tmp_path):
    """A worker that dies holding a lease never stalls the run: its step
    is re-issued and every update still lands, bit-replayable."""
    log = str(tmp_path / "death.jsonl")
    grade = get_grade("flagship")
    base = lease_latency_s(CFG, grade, 2 * 16, MZ.n_directions)
    workers = [WorkerSpec("flagship", FaultSpec(jitter=0.1)),
               # dies mid-flight of an early lease, result discarded
               WorkerSpec("flagship", FaultSpec(die_at=base * 1.5))]
    sim = FleetSim(CFG, workers, total_steps=8, mezo_cfg=MZ, batch=2,
                   seq=16, seed=1, log_path=log)
    rep = sim.run()
    assert rep.applied == 8
    assert sorted(r["step"] for r in rep.records) == list(range(8))
    p0 = sim.model.init(jax.random.PRNGKey(1))
    replayed, _ = replay_into(p0, ReplayLog.read(log), MZ)
    assert _max_diff(replayed, rep.params) == 0.0


def test_late_and_duplicate_deliveries_dropped_not_logged(tmp_path):
    """First delivery wins; late re-issue results and transport
    duplicates are counted but never reach the log (no divergent-retry
    warning on read)."""
    log = str(tmp_path / "dup.jsonl")
    workers = [WorkerSpec("flagship", FaultSpec(duplicate_every=1)),
               WorkerSpec("flagship", FaultSpec(jitter=0.1)),
               WorkerSpec("flagship", FaultSpec(latency_scale=8.0))]
    sim = FleetSim(CFG, workers, total_steps=10, mezo_cfg=MZ, batch=2,
                   seq=16, seed=2, log_path=log)
    rep = sim.run()
    assert rep.applied == 10
    assert rep.dropped > 0
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # any warning fails
        recs = ReplayLog.read(log)
    assert len(recs) == 10                       # one record per step


def test_join_and_leave_resize_policy_and_params():
    coord_cfg = dict(total_steps=4, n_workers=2, seed=0)
    sim_params = {"w": jnp.ones((4, 4), jnp.float32)}
    c = FleetCoordinator(sim_params, MZ, **coord_cfg)
    c._observe(0, 1.0)
    assert c.policy.total == 2
    wid = c.worker_join(now=0.0)
    assert wid == 2 and c.policy.total == 3
    # newcomer's EMA seeded with the fleet median, not zero
    assert c.policy.ema_latencies[-1] > 0
    c.worker_leave(0, now=0.0)
    assert c.policy.total == 2
    assert c.resizes == 2
    with pytest.raises(ValueError, match="not in the roster"):
        c.worker_leave(99, now=0.0)


def test_leave_orphans_inflight_leases_for_reissue():
    params = {"w": jnp.ones((4,), jnp.float32)}
    c = FleetCoordinator(params, MZ, total_steps=3, n_workers=2, seed=0)
    lease = c.next_lease(worker=1, now=0.0)
    assert lease.step == 0
    c.worker_leave(1, now=0.0)
    release = c.next_lease(worker=0, now=0.0)
    assert release.step == 0                    # orphaned step re-issued
    assert c.reissued == 1


def test_stale_sgd_staleness_zero_matches_sgd_bit_exact():
    params = {"w": jnp.linspace(-1, 1, 32, dtype=jnp.float32)}
    gs = np.array([0.3, -0.7], np.float32)
    a, _ = SGD.update_fn(params, {}, np.uint32(7), gs, None, MZ)
    b, _ = STALE_SGD.update_fn(params, {}, np.uint32(7), gs, None, MZ)
    c, _ = STALE_SGD.update_fn(params, {}, np.uint32(7), gs, None, MZ,
                               staleness=0)
    assert _max_diff(a, b) == 0.0
    assert _max_diff(a, c) == 0.0
    d, _ = STALE_SGD.update_fn(params, {}, np.uint32(7), gs, None, MZ,
                               staleness=3)
    assert _max_diff(a, d) > 0.0                # decay actually applied


def test_coordinator_validates_config():
    params = {"w": jnp.ones((2,), jnp.float32)}
    with pytest.raises(ValueError, match="total_steps"):
        FleetCoordinator(params, MZ, total_steps=0, n_workers=1)
    with pytest.raises(ValueError, match="staleness_decay"):
        FleetCoordinator(params,
                         MezoConfig(staleness_decay=0.0),
                         total_steps=1, n_workers=1)
    with pytest.raises(ValueError, match="pristine"):
        FleetSim(CFG, [WorkerSpec()], total_steps=1, estimator="walk")
    with pytest.raises(ValueError, match="unknown device grade"):
        get_grade("abacus")
    with pytest.raises(ValueError, match="never fire"):
        FleetSim(CFG, [WorkerSpec()], total_steps=2,
                 step_events=[(2, "join", WorkerSpec())]).run()


def test_lease_latency_orders_device_grades():
    fast = lease_latency_s(CFG, get_grade("flagship"), 64, 2)
    slow = lease_latency_s(CFG, get_grade("budget"), 64, 2)
    assert 0 < fast < slow
    assert lease_latency_s(CFG, get_grade("flagship"), 64, 4) > fast


# ---------------------------------------------------------------------------
# regression pins: the bugfix sweep


def test_pipeline_close_joins_worker_with_full_queue():
    """Shutdown deadlock pin: close() while the worker is blocked on a
    full queue must join the thread promptly, not hang forever."""
    def endless():
        while True:
            yield {"x": np.zeros(4)}

    pipe = DataPipeline(endless(), prefetch=1)
    next(pipe)                         # worker now refilling a full queue
    t0 = time.monotonic()
    pipe.close()
    assert time.monotonic() - t0 < 5.0
    assert not pipe._thread.is_alive()


def test_pipeline_next_after_close_raises_not_hangs():
    pipe = DataPipeline(iter([{"x": np.zeros(2)}]), prefetch=1)
    pipe.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(pipe)


def test_pipeline_next_after_worker_error_raises_not_hangs():
    def boom():
        raise ValueError("source died")
        yield  # pragma: no cover

    pipe = DataPipeline(boom())
    with pytest.raises(ValueError):
        next(pipe)
    # the queue is empty and the worker is gone: a second next() must
    # fail fast instead of blocking on q.get() forever
    with pytest.raises(RuntimeError, match="worker raised ValueError"):
        next(pipe)


def test_pipeline_exhaustion_keeps_raising_stopiteration():
    pipe = DataPipeline(iter([{"x": np.zeros(2)}]))
    assert len(list(pipe)) == 1
    with pytest.raises(StopIteration):          # iterator protocol holds
        next(pipe)


def test_replay_log_conflicting_duplicate_warns(tmp_path):
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    log.append(0, 7, [0.1], lr=1e-3, eps=1e-3)
    log.append(1, 8, [0.2], lr=1e-3, eps=1e-3)
    log.append(1, 8, [0.2], lr=1e-3, eps=1e-3)   # benign retry
    log.append(0, 9, [0.5], lr=1e-3, eps=1e-3)   # divergent retry!
    log.close()
    with pytest.warns(RuntimeWarning, match="conflicting duplicate"):
        recs = ReplayLog.read(path)
    assert [r["step"] for r in recs] == [0, 1]
    assert recs[0]["seed"] == 7                  # first-applied wins

    benign = str(tmp_path / "benign.jsonl")
    log = ReplayLog(benign)
    log.append(0, 7, [0.1], lr=1e-3, eps=1e-3)
    log.append(0, 7, [0.1], lr=1e-3, eps=1e-3)
    log.close()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert len(ReplayLog.read(benign)) == 1  # silent dedup


def test_straggler_observe_shape_error_names_expectation():
    pol = StragglerPolicy(n_directions=4, redundancy=2)
    with pytest.raises(ValueError, match=r"\(6,\)"):
        pol.observe([1.0, 2.0])


def test_straggler_deadline_inf_until_seen_then_median_scaled():
    pol = StragglerPolicy(n_directions=4, deadline_factor=3.0)
    assert pol.deadline() == float("inf")
    pol.observe([1.0, 1.0, 2.0, 4.0])
    assert pol.deadline() == pytest.approx(3.0 * 1.5)
    # copy-trick: feeding an entry's own EMA back leaves it unchanged
    vec = pol.ema_latencies
    vec[0] = 10.0
    pol.observe(vec)
    np.testing.assert_allclose(pol.ema_latencies[1:], [1.0, 2.0, 4.0])


def test_mesh_shape_for_warns_on_stranded_devices():
    with pytest.warns(RuntimeWarning, match="stranding 8 of 24"):
        shape = mesh_shape_for(24, model_parallel=4, data_parallel=4)
    assert shape == (1, 4, 4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert mesh_shape_for(32, 4, 4) == (2, 4, 4)   # exact fit: silent
