"""RWKV-6 "Finch" layer: linear attention with data-dependent decay.

Time-mix state per head is an (hd x hd) outer-product accumulator with a
per-channel, *input-dependent* decay w_t (the RWKV6 signature, via a
low-rank MLP on the shifted input). Channel-mix is the squared-ReLU RWKV
FFN. Full-sequence form scans over time; decode is the same cell applied
once -- O(1) state, which is why rwkv6 is assigned the long_500k shape.

Simplification vs. the released Finch: token-shift interpolation factors
(mu_*) are static learned vectors rather than data-dependent LoRAs; the
decay LoRA (the architecturally significant part) is kept faithful.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.models import layers as L
from repro.models.sharding import maybe_shard
from repro.optim.quant import deq as _deq


def _leaf(p, name, ctx):
    """p[name] + coeff*z under a PerturbCtx; the bare (dequantized) leaf
    without one. Threading the ctx through every weight use is what
    gives rwkv6 the fused ZO loss (no transient parameter copy)."""
    return _deq(p[name]) if ctx is None else ctx.perturb(name, p[name])


def _heads(cfg):
    hd = cfg.rwkv_head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def timemix_init(cfg, key):
    d = cfg.d_model
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 8)
    dt = L._dt(cfg)
    lora = 64 if d >= 512 else 16
    return {
        "mu": (jax.random.normal(ks[0], (5, d), jnp.float32) * 0.02),
        "wr": L.dense_init(ks[1], d, d, dt),
        "wk": L.dense_init(ks[2], d, d, dt),
        "wv": L.dense_init(ks[3], d, d, dt),
        "wg": L.dense_init(ks[4], d, d, dt),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_lora_a": (jax.random.normal(ks[5], (d, lora), jnp.float32) * 0.02),
        "w_lora_b": jnp.zeros((lora, d), jnp.float32),
        "bonus": (jax.random.normal(ks[6], (h, hd), jnp.float32) * 0.02),
        "ln_x": jnp.ones((d,), jnp.float32),
        "wo": L.dense_init(ks[7], d, d, dt,
                           scale=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` at t=0). x: (B, S, D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _timemix_inputs(cfg, p, x, x_prev, ctx=None):
    xx = x_prev - x
    mu = _leaf(p, "mu", ctx).astype(x.dtype)
    xr, xk, xv, xw, xg = (x + xx * mu[i] for i in range(5))
    h, hd = _heads(cfg)
    b, s, d = x.shape
    r = L.dense(p["wr"], xr, _sub(ctx, "wr")).reshape(b, s, h, hd)
    k = L.dense(p["wk"], xk, _sub(ctx, "wk")).reshape(b, s, h, hd)
    v = L.dense(p["wv"], xv, _sub(ctx, "wv")).reshape(b, s, h, hd)
    g = jax.nn.silu(L.dense(p["wg"], xg, _sub(ctx, "wg")))
    # data-dependent per-channel decay in (0, 1)
    wlog = (_leaf(p, "w0", ctx)
            + jnp.tanh(xw.astype(jnp.float32) @ _leaf(p, "w_lora_a", ctx))
            @ _leaf(p, "w_lora_b", ctx))
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, h, hd)
    return r, k, v, g, w


def _wkv_cell(state, r_t, k_t, v_t, w_t, bonus):
    """state: (B, H, hd, hd) keyed [k-dim, v-dim]."""
    kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
    y = jnp.einsum("bhk,bhkv->bhv", r_t, state + bonus[..., :, None] * kv)
    state = w_t[..., :, None] * state + kv
    return state, y


def timemix_apply(cfg, p, x, state=None, x_prev=None, ctx=None):
    """x: (B,S,D). state: (B,H,hd,hd) f32 or None. Returns y, (state, x_last)."""
    b, s, d = x.shape
    h, hd = _heads(cfg)
    xp = _shift(x, x_prev)
    r, k, v, g, w = _timemix_inputs(cfg, p, x, xp, ctx)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    # pin the scan state head-sharded over the model axis: without this
    # anchor XLA replicated the whole WKV scan across all 16 model shards
    # once the CE collective stopped forcing a sharded layout (measured:
    # 7x per-chip flops on rwkv6 train_4k). Constraining ONLY the carry
    # lets sharding propagate to r/k/v/w without forcing extra reshards
    # (constraining all five cost 2x collectives -- Sec Perf addendum).
    state = maybe_shard(state, None, "model", None, None)
    bonus = _leaf(p, "bonus", ctx)[None]

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp
        st, y = _wkv_cell(st, r_t.astype(jnp.float32),
                          k_t.astype(jnp.float32), v_t.astype(jnp.float32),
                          w_t, bonus)
        return st, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    y = L.rmsnorm(y.astype(x.dtype), _leaf(p, "ln_x", ctx)) * g
    return L.dense(p["wo"], y, _sub(ctx, "wo")), (state, x[:, -1:])


def channelmix_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = L._dt(cfg)
    return {
        "mu": (jax.random.normal(ks[0], (2, d), jnp.float32) * 0.02),
        "wr": L.dense_init(ks[1], d, d, dt),
        "wk": L.dense_init(ks[2], d, f, dt),
        "wv": L.dense_init(jax.random.fold_in(ks[2], 1), f, d, dt,
                           scale=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }


def channelmix_apply(cfg, p, x, x_prev=None, ctx=None):
    xp = _shift(x, x_prev)
    xx = xp - x
    mu = _leaf(p, "mu", ctx).astype(x.dtype)
    xk, xr = x + xx * mu[0], x + xx * mu[1]
    r = jax.nn.sigmoid(L.dense(p["wr"], xr, _sub(ctx, "wr")))
    k = jnp.square(jax.nn.relu(L.dense(p["wk"], xk, _sub(ctx, "wk"))))
    return r * L.dense(p["wv"], k, _sub(ctx, "wv")), x[:, -1:]
