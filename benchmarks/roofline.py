"""Aggregate dry-run artifacts into the roofline table (EXPERIMENTS.md).

Reads experiments/dryrun/*.json and renders per-(arch x shape x mesh):
three roofline terms, bottleneck, MODEL_FLOPS ratio, roofline fraction.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict


def load_records(dd="experiments/dryrun"):
    recs = []
    if not os.path.isdir(dd):
        return recs
    for f in sorted(os.listdir(dd)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(dd, f))))
    return recs


def markdown_table(recs, mesh_tag="pod16x16"):
    lines = [
        "| arch | shape | opt | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bottleneck | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh_tag") != mesh_tag:
            continue
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                         f"SKIP: {r['reason'][:48]} | - | - |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                         f"FAIL | - | - |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('optimizer')} "
            f"| {t['t_compute_s']:.3e} | {t['t_memory_s']:.3e} "
            f"| {t['t_collective_s']:.3e} | {t['bottleneck']} "
            f"| {t.get('useful_flops_ratio', 0):.3f} "
            f"| {t.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    recs = load_records()
    rows = []
    ok = [r for r in recs if r.get("status") == "ok"]
    by_bottleneck = defaultdict(int)
    for r in ok:
        by_bottleneck[r["roofline"]["bottleneck"]] += 1
        rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh_tag']}",
                     r["roofline"]["t_compute_s"] * 1e6,
                     f"bottleneck={r['roofline']['bottleneck']};"
                     f"frac={r['roofline'].get('roofline_fraction', 0):.4f}"))
    for tag in ("pod16x16", "pod2x16x16"):
        md = markdown_table(recs, tag)
        with open(os.path.join(out_dir, f"roofline_{tag}.md"), "w") as f:
            f.write(md + "\n")
    rows.append(("roofline/summary", 0.0,
                 ";".join(f"{k}={v}" for k, v in sorted(
                     by_bottleneck.items()))))
    return rows
