"""Elastic scaling for ZO training.

Because params are replicated across the ``pod`` axis and cross-pod state
is only the per-step (seed, gs) scalars, pods joining or leaving changes
*nothing* about parameter sharding -- only the direction count K. Elastic
events therefore cost:

  * pod join:  broadcast params into the new pod (one transfer), K += k
  * pod leave: K -= k, continue same step (ZO drop-direction semantics)

``elastic_mesh`` rebuilds the mesh for the current device count;
``remesh_params`` moves live params onto it (a device_put resharding; for
a same-(data,model)-topology change this is pod-broadcast only).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models import sharding as shd

PyTree = Any


def elastic_mesh(devices=None, model_parallel: int = 16,
                 data_parallel: int = 16):
    """Mesh for however many devices are currently alive.

    Keeps the intra-pod (data, model) topology fixed (so param shardings
    stay valid) and absorbs device-count changes into the pod axis.
    Falls back to shrinking data_parallel when fewer than one pod's
    devices remain (degraded single-pod mode).
    """
    devices = np.asarray(devices if devices is not None else jax.devices())
    per_pod = model_parallel * data_parallel
    n = devices.size
    if n >= per_pod:
        pods = n // per_pod
        devs = devices[: pods * per_pod].reshape(pods, data_parallel,
                                                 model_parallel)
        return Mesh(devs, ("pod", "data", "model"))
    # degraded: one partial pod -- keep model axis, shrink data axis
    dp = max(1, n // model_parallel)
    if dp * model_parallel > n:
        model_parallel = n
        dp = 1
    devs = devices[: dp * model_parallel].reshape(1, dp, model_parallel)
    return Mesh(devs, ("pod", "data", "model"))


def remesh_params(params: PyTree, new_mesh: Mesh) -> PyTree:
    """Reshard live params onto a new mesh (pod join/leave)."""
    specs = shd.spec_tree(params)
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(new_mesh, s)),
        params, specs)
