"""whisper-base [audio]: enc-dec; conv frontend is a STUB -- input_specs()
provides precomputed frame embeddings. [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
        act="gelu", norm="layernorm", pos="learned",
        enc_layers=6, dec_layers=6, enc_len=1500, use_tp=False,
        max_seq=32768)
