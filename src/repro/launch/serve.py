"""Serving launcher: batched prefill + decode against a KV cache.

The personalized-LLM story of the paper is fine-tune-then-serve on the
same device; this driver serves a (possibly ZO-fine-tuned) checkpoint
with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 4 --prompt-len 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model


def serve(cfg, params, prompts: np.ndarray, gen: int, greedy: bool = True):
    """prompts: (B, P) int32. Returns (B, gen) generated tokens."""
    model = build_model(cfg)
    bsz, plen = prompts.shape
    cache = model.init_cache(bsz, plen + gen)
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    toks = jnp.asarray(prompts)
    out = []
    last = None
    for t in range(plen + gen - 1):
        # prefill token-by-token through the decode path (exercises the
        # same cell the dry-run lowers; a fused prefill is a perf option)
        if t < plen:
            cur = toks[:, t:t + 1]
        else:
            cur = last
            out.append(np.asarray(cur))
        logits, cache = step(params, cache, cur, jnp.int32(t))
        last = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32) \
            if greedy else jnp.asarray(
                jax.random.categorical(jax.random.PRNGKey(t),
                                       logits[:, -1, :])[:, None],
                jnp.int32)
    out.append(np.asarray(last))
    return np.concatenate(out, axis=1)[:, :gen]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        step = store.latest_step(args.ckpt_dir)
        if step is not None:
            params = store.load_params(args.ckpt_dir, step, params)
            print(f"[serve] loaded checkpoint step {step}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                           dtype=np.int32)
    t0 = time.perf_counter()
    toks = serve(cfg, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} reqs x ({args.prompt_len} prompt + "
          f"{args.gen} gen) in {dt:.2f}s")
    print(toks)


if __name__ == "__main__":
    main()
