"""granite-moe-1b-a400m [moe]: 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=512, vocab=49155,
        act="swiglu", norm="rmsnorm", pos="rope", tie_embeddings=True,
        n_experts=32, topk=8, expert_dff=512, capacity_factor=1.25, moe_ep=True,
        max_seq=32768)
