import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: AOT lower+compile every (arch x shape) cell on the
production mesh, with 512 placeholder host devices standing in for the
2-pod v5e slice. Proves the distribution config is coherent: sharding
mismatches, compile-time OOMs and unsupported collectives all fail here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      [--optimizer mezo|mezo-parallel|adam] [--out experiments/dryrun]

Outputs one JSON per cell: memory_analysis, cost_analysis, collective
bytes (parsed from the partitioned HLO), analytic per-device bytes, and
the three roofline terms.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.mezo import MezoConfig, mezo_step, mezo_step_vmapdir
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.optim.adam import AdamConfig, adam_init, grad_train_step
from repro.roofline.analysis import (active_params, roofline_terms,
                                     total_params)


def _analytic_bytes_per_device(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        shard = leaf.sharding.shard_shape(leaf.shape) \
            if getattr(leaf, "sharding", None) else leaf.shape
        total += int(np.prod(shard)) * leaf.dtype.itemsize
    return total


def lower_cell(arch: str, shape_name: str, mesh, optimizer: str = "mezo",
               mezo_cfg: MezoConfig = None, cfg_overrides=None):
    """Returns (lowered, meta). Raises on unsupported cells."""
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    inp = S.cell_inputs(cfg, shape_name, mesh)
    model = inp["model"]
    mcfg = mezo_cfg or MezoConfig(n_directions=1)
    meta = dict(arch=arch, shape=shape_name, mode=inp["mode"],
                optimizer=optimizer if inp["mode"] == "train" else "fwd",
                mesh=dict(axes=list(mesh.axis_names),
                          shape=[int(s) for s in mesh.devices.shape]))
    sh = S.SHAPES[shape_name]
    meta["n_tokens"] = sh["batch"] * (sh["seq"] if inp["mode"] != "decode"
                                      else 1)
    meta["analytic_param_bytes_per_device"] = _analytic_bytes_per_device(
        inp["params"])

    if inp["mode"] == "train":
        if optimizer == "adam":
            state = jax.eval_shape(adam_init, inp["params"])
            state = S._with_shardings(
                state, shd.spec_tree(state, fsdp=cfg.fsdp_params), mesh)
            meta["analytic_opt_bytes_per_device"] = \
                _analytic_bytes_per_device(state)
            lowered = grad_train_step.lower(model.loss, inp["params"],
                                            inp["batch"], state,
                                            AdamConfig())
        else:
            step = {"mezo": mezo_step, "mezo-parallel": mezo_step_vmapdir}
            lowered = step[optimizer].lower(model.loss, inp["params"],
                                            inp["batch"], inp["seed"], mcfg,
                                            None)
            meta["analytic_opt_bytes_per_device"] = 0
    elif inp["mode"] == "prefill":
        fn = jax.jit(lambda p, b: model.forward(p, b, last_only=True))
        lowered = fn.lower(inp["params"], inp["batch"])
    else:  # decode
        meta["analytic_cache_bytes_per_device"] = _analytic_bytes_per_device(
            inp["cache"])
        fn = jax.jit(model.decode_step, donate_argnums=(1,))
        lowered = fn.lower(inp["params"], inp["cache"], inp["tokens"],
                           inp["pos"])
    return lowered, meta, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             optimizer: str = "mezo", out_dir: str = None,
             verbose: bool = True, cfg_overrides=None, tag: str = None):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    if tag:
        mesh_tag = f"{mesh_tag}+{tag}"
    cfg = get_config(arch)
    reason = S.cell_supported(cfg, shape_name)
    rec = dict(arch=arch, shape=shape_name, mesh_tag=mesh_tag,
               optimizer=optimizer)
    if reason:
        rec.update(status="skip", reason=reason)
        _emit(rec, out_dir, verbose)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            lowered, meta, cfg = lower_cell(arch, shape_name, mesh,
                                            optimizer,
                                            cfg_overrides=cfg_overrides)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        _emit(rec, out_dir, verbose)
        return rec

    rec.update(meta)
    rec.update(status="ok", t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1))

    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in dir(ma)
            if k.endswith("size_in_bytes") and not k.startswith("_")}
    except Exception as e:
        rec["memory_analysis"] = {"unavailable": str(e)[:200]}

    try:
        cost = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in cost.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "optimal_seconds")}
    except Exception as e:
        cost = {}
        rec["cost_analysis"] = {"unavailable": str(e)[:200]}

    hlo = None
    try:
        hlo = compiled.as_text()
    except Exception:
        try:
            hlo = lowered.as_text()
        except Exception:
            pass

    n_chips = int(np.prod(mesh.devices.shape))
    rec["n_params_total"] = float(total_params(cfg))
    rec["n_params_active"] = float(active_params(cfg))
    rec["roofline"] = roofline_terms(
        cost if isinstance(cost, dict) else {}, hlo, n_chips, cfg=cfg,
        n_tokens=rec["n_tokens"],
        mode=("train" if rec.get("optimizer") in ("mezo", "mezo-parallel")
              else ("train-adam" if rec.get("optimizer") == "adam"
                    else rec["mode"])))
    if hlo:
        from repro.roofline.hlo import collective_bytes
        rec["collectives"] = collective_bytes(hlo)
        if out_dir:  # persist HLO for offline (re-)analysis / perf work
            import gzip
            os.makedirs(out_dir, exist_ok=True)
            hname = (f"{rec['arch']}__{rec['shape']}__{rec['mesh_tag']}"
                     f"__{rec.get('optimizer', 'na')}.hlo.gz")
            with gzip.open(os.path.join(out_dir, hname), "wt") as f:
                f.write(hlo)
    _emit(rec, out_dir, verbose)
    return rec


def _emit(rec, out_dir, verbose):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh_tag']}"
                f"__{rec.get('optimizer','na')}.json")
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"[dryrun] OK  {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh_tag']:10s} bottleneck={r['bottleneck']:10s} "
                  f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                  f"tx={r['t_collective_s']:.3e}")
        elif rec["status"] == "skip":
            print(f"[dryrun] SKIP {rec['arch']:24s} {rec['shape']:12s} "
                  f"({rec['reason'][:60]})")
        else:
            print(f"[dryrun] FAIL {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['error'][:200]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="mezo",
                    choices=["mezo", "mezo-parallel", "adam"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--moe-ep", action="store_true",
                    help="shard_map expert parallelism (perf opt)")
    ap.add_argument("--tag", default=None,
                    help="suffix for output filenames (perf iterations)")
    args = ap.parse_args()

    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                ovr = {"moe_ep": True} if args.moe_ep else None
                rec = run_cell(arch, shape, mp, args.optimizer, args.out,
                               cfg_overrides=ovr, tag=args.tag)
                n_fail += rec["status"] == "fail"
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
