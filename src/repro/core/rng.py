"""Counter-based hash RNG for seed-replay perturbations.

PocketLLM / MeZO's memory trick is that the perturbation ``z`` is *never
stored* -- it is regenerated from a PRNG seed at every use (perturb,
un-perturb, update). On TPU we additionally want to regenerate ``z`` tiles
*inside* a Pallas kernel so that ``z`` never touches HBM. That requires a
counter-based (stateless, coordinate-addressable) RNG whose output for
element ``(i0, i1, ...)`` of a leaf depends only on ``(seed, leaf_id,
coords)`` -- identical whether evaluated by the pure-jnp reference, the
fused kernel, or the update path.

We use an xxhash/murmur-style integer avalanche over per-dimension iotas.
This is NOT a cryptographic RNG; it only needs to be a good-enough source
of i.i.d. signs/gaussians for SPSA (Spall 1992), which is robust to mild
RNG imperfection. All arithmetic is uint32 with wraparound semantics.
"""

from __future__ import annotations

import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Distinct odd multipliers per dimension (first 8 dims supported; models
# here never exceed 5-D leaves). Values are standard hash-mixing primes.
_DIM_PRIMES = (
    0x9E3779B1,  # golden-ratio prime
    0x85EBCA77,
    0xC2B2AE3D,
    0x27D4EB2F,
    0x165667B1,
    0xD3A2646D,
    0xFD7046C5,
    0xB55A4F09,
)

_U32 = jnp.uint32


def avalanche(x):
    """Final xxhash32-style avalanche: full-period bijection on uint32."""
    x = x.astype(_U32) if hasattr(x, "astype") else jnp.asarray(x, _U32)
    x = x ^ (x >> 15)
    x = x * _U32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * _U32(0x297A2D39)
    x = x ^ (x >> 15)
    return x


def leaf_salt(path: str) -> int:
    """Stable per-leaf salt from the pytree path (python int, trace-time)."""
    return zlib.crc32(path.encode("utf-8")) & 0xFFFFFFFF


def fold_seed(seed, k):
    """Derive a sub-seed (e.g. per perturbation direction k). Traced-safe."""
    s = jnp.asarray(seed, _U32)
    return avalanche(s ^ (jnp.asarray(k, _U32) * _U32(_DIM_PRIMES[1])))


def _coord_hash(seed, salt: int, shape, offsets=None):
    """uint32 hash field over an index grid of ``shape``.

    offsets: optional per-dim start indices (used by Pallas tiles so a tile
    at block (i, j) reproduces the same values as the full-array reference).
    """
    if len(shape) > len(_DIM_PRIMES):
        raise ValueError(f"leaf rank {len(shape)} > {len(_DIM_PRIMES)} unsupported")
    h = avalanche(jnp.asarray(seed, _U32) ^ _U32(salt))
    if len(shape) == 0:
        return avalanche(h)
    for d, n in enumerate(shape):
        iota = jax.lax.broadcasted_iota(_U32, shape, d)
        if offsets is not None:
            iota = iota + jnp.asarray(offsets[d], _U32)
        h = avalanche(h ^ (iota * _U32(_DIM_PRIMES[d % len(_DIM_PRIMES)])))
    return h


def rademacher_field(seed, salt: int, shape, dtype=jnp.float32, offsets=None):
    """±1 field, one hash per element (default ZO perturbation)."""
    bits = _coord_hash(seed, salt, shape, offsets)
    sign = 1.0 - 2.0 * (bits >> 31).astype(jnp.float32)
    return sign.astype(dtype)


def gaussian_field(seed, salt: int, shape, dtype=jnp.float32, offsets=None):
    """N(0,1) field via Box-Muller on two decorrelated hash fields."""
    h1 = _coord_hash(seed, salt, shape, offsets)
    h2 = avalanche(h1 ^ _U32(0x68E31DA4))
    # uniforms in (0, 1]: use top 24 bits, add 1 ulp to avoid log(0)
    u1 = ((h1 >> 8).astype(jnp.float32) + 1.0) * (1.0 / 16777216.0)
    u2 = (h2 >> 8).astype(jnp.float32) * (1.0 / 16777216.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = (2.0 * np.pi) * u2
    return (r * jnp.cos(theta)).astype(dtype)


def z_field(seed, salt: int, shape, dtype=jnp.float32, dist: str = "rademacher",
            offsets=None):
    if dist == "rademacher":
        return rademacher_field(seed, salt, shape, dtype, offsets)
    if dist == "gaussian":
        return gaussian_field(seed, salt, shape, dtype, offsets)
    raise ValueError(f"unknown zo distribution: {dist}")
