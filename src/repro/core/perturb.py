"""Seed-replay pytree perturbation: theta <- theta + c * z(seed).

The perturbation z is regenerated from (seed, leaf-path) on every call and
is never stored across calls -- the live footprint is one transient
leaf-sized buffer at a time, which XLA fuses into the add. This is the
functional-JAX rendering of MeZO's in-place ``torch.normal_``-replay trick
(PocketLLM Sec. 3.3).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import rng as zrng
from repro.optim.quant import is_quantized

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def leaf_salts(params: PyTree) -> PyTree:
    """Static per-leaf salts (python ints), same structure as params.

    Quantized leaves are atomic here (the salt binds to the *leaf's*
    path, never ``.../q``), so a quantized base shares every salt with
    its f32 counterpart -- replay logs move freely between the two.
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_quantized)
    salts = [zrng.leaf_salt(_path_str(path)) for path, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, salts)


def is_perturbable(path_str: str) -> bool:
    """Which leaves receive ZO noise. Everything trainable by default."""
    return True


def kernel_aligned(shape) -> bool:
    """MXU tile-alignment gate for routing a leaf through the Pallas ZO
    kernels (zo_add / zo_matmul); the single source of truth for both the
    perturb-sweep and fused-forward paths."""
    return len(shape) == 2 and shape[0] % 8 == 0 and shape[1] % 128 == 0


def add_scaled_z(params: PyTree, seed, coeff, dist: str = "rademacher",
                 use_kernel: bool = False) -> PyTree:
    """theta + coeff * z(seed), leaf-wise, z regenerated (never stored).

    ``coeff`` may be a traced scalar (e.g. ``eps - lr * g`` fusing the
    restore and update passes of MeZO into a single sweep).

    use_kernel: route large 2-D leaves through the Pallas fused kernel
    (repro.kernels.ops.zo_add) instead of jnp; identical values by
    construction of the hash RNG.

    Quantized leaves (optim/quant.py): the int8 base is frozen, so the
    scaled z lands in the f32 ``delta`` (same z-field as the f32
    counterpart -- the salt binds to the leaf's path, not ``.../q``). A
    delta-less quantized leaf is a *frozen* base and passes through
    untouched; attach deltas with ``quant.with_delta`` before training.
    """
    coeff = jnp.asarray(coeff, jnp.float32)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_quantized)
    out = []
    for path, leaf in leaves:
        ps = _path_str(path)
        if not is_perturbable(ps) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(leaf)
            continue
        salt = zrng.leaf_salt(ps)
        if is_quantized(leaf):
            if leaf.delta is None:
                out.append(leaf)
            elif use_kernel and kernel_aligned(leaf.shape):
                from repro.kernels import ops as kops  # lazy: pallas import
                out.append(dataclasses.replace(leaf, delta=kops.zo_add(
                    leaf.delta, seed, salt, coeff, dist=dist)))
            else:
                z = zrng.z_field(seed, salt, leaf.shape, jnp.float32, dist)
                out.append(dataclasses.replace(leaf,
                                               delta=leaf.delta + coeff * z))
            continue
        if use_kernel and kernel_aligned(leaf.shape):
            from repro.kernels import ops as kops  # lazy: pallas import
            out.append(kops.zo_add(leaf, seed, salt, coeff, dist=dist))
        else:
            z = zrng.z_field(seed, salt, leaf.shape, jnp.float32, dist)
            out.append((leaf.astype(jnp.float32) + coeff * z).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


def dot_with_z(params_like: PyTree, seed, tangent: PyTree,
               dist: str = "rademacher"):
    """<tangent, z(seed)> -- used by tests to cross-check the estimator."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(
        params_like, is_leaf=is_quantized)
    tleaves = jax.tree_util.tree_leaves(tangent, is_leaf=is_quantized)
    acc = jnp.float32(0.0)
    for (path, leaf), t in zip(leaves, tleaves):
        if is_quantized(t):
            t = t.dequantize_f32()
        ps = _path_str(path)
        if not is_perturbable(ps) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        z = zrng.z_field(seed, zrng.leaf_salt(ps), leaf.shape, jnp.float32, dist)
        acc = acc + jnp.vdot(t.astype(jnp.float32), z)
    return acc
