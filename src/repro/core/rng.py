"""Counter-based hash RNG for seed-replay perturbations.

PocketLLM / MeZO's memory trick is that the perturbation ``z`` is *never
stored* -- it is regenerated from a PRNG seed at every use (perturb,
un-perturb, update). On TPU we additionally want to regenerate ``z`` tiles
*inside* a Pallas kernel so that ``z`` never touches HBM. That requires a
counter-based (stateless, coordinate-addressable) RNG whose output for
element ``(i0, i1, ...)`` of a leaf depends only on ``(seed, leaf_id,
coords)`` -- identical whether evaluated by the pure-jnp reference, the
fused kernel, or the update path.

We use an xxhash/murmur-style integer avalanche over per-dimension iotas.
This is NOT a cryptographic RNG; it only needs to be a good-enough source
of i.i.d. signs/gaussians for SPSA (Spall 1992), which is robust to mild
RNG imperfection. All arithmetic is uint32 with wraparound semantics.
"""

from __future__ import annotations

import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Distinct odd multipliers per dimension (first 8 dims supported; models
# here never exceed 5-D leaves). Values are standard hash-mixing primes.
_DIM_PRIMES = (
    0x9E3779B1,  # golden-ratio prime
    0x85EBCA77,
    0xC2B2AE3D,
    0x27D4EB2F,
    0x165667B1,
    0xD3A2646D,
    0xFD7046C5,
    0xB55A4F09,
)

_U32 = jnp.uint32


def avalanche(x):
    """Final xxhash32-style avalanche: full-period bijection on uint32."""
    x = x.astype(_U32) if hasattr(x, "astype") else jnp.asarray(x, _U32)
    x = x ^ (x >> 15)
    x = x * _U32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * _U32(0x297A2D39)
    x = x ^ (x >> 15)
    return x


def leaf_salt(path: str) -> int:
    """Stable per-leaf salt from the pytree path (python int, trace-time)."""
    return zlib.crc32(path.encode("utf-8")) & 0xFFFFFFFF


def fold_seed(seed, k):
    """Derive a sub-seed (e.g. per perturbation direction k). Traced-safe."""
    s = jnp.asarray(seed, _U32)
    return avalanche(s ^ (jnp.asarray(k, _U32) * _U32(_DIM_PRIMES[1])))


def leaf_base(seed, salt: int):
    """Pre-hashed starting state of a leaf's field: avalanche(seed ^ salt).

    Passing it as ``base=`` to the field constructors (or ``prehashed=True``
    to the Pallas kernels) skips the seed/salt fold, which lets callers fold
    *leading* coordinates in first -- see :func:`fold_leading`.
    """
    return avalanche(jnp.asarray(seed, _U32) ^ _U32(salt))


def fold_leading(base, idx, dim: int = 0):
    """Advance a pre-hashed base past one leading coordinate.

    For a stacked leaf of shape ``(L, *s)`` (e.g. scan-stacked per-layer
    weights) the slice at layer ``l`` satisfies

      z_field(seed, salt, (L, *s))[l]
        == z_field(None, 0, s, base=fold_leading(leaf_base(seed, salt), l),
                   prime_offset=1)

    because :func:`_coord_hash` folds dimensions outermost-first. ``idx``
    may be traced (a scan counter).
    """
    return avalanche(jnp.asarray(base, _U32)
                     ^ (jnp.asarray(idx, _U32) * _U32(_DIM_PRIMES[dim])))


def _coord_hash(seed, salt: int, shape, offsets=None, prime_offset: int = 0,
                base=None):
    """uint32 hash field over an index grid of ``shape``.

    offsets: optional per-dim start indices (used by Pallas tiles so a tile
    at block (i, j) reproduces the same values as the full-array reference).
    prime_offset: index of the per-dimension prime used for dim 0 -- a slice
    of a higher-rank leaf keeps its original dims' primes this way.
    base: optional pre-hashed state (see :func:`leaf_base`); seed/salt are
    ignored when given.
    """
    if len(shape) + prime_offset > len(_DIM_PRIMES):
        raise ValueError(
            f"leaf rank {len(shape)} + offset {prime_offset} > "
            f"{len(_DIM_PRIMES)} unsupported")
    if base is None:
        h = leaf_base(seed, salt)
    else:
        h = jnp.asarray(base, _U32)
    if len(shape) == 0:
        # a true scalar leaf gets one extra avalanche; a rank-0 *slice*
        # (prime_offset > 0, base pre-folded past the leading dims) must
        # not -- fold_leading already avalanched, and the full-field
        # reference applies no further mixing to that element
        return avalanche(h) if prime_offset == 0 else h
    for d, n in enumerate(shape):
        iota = jax.lax.broadcasted_iota(_U32, shape, d)
        if offsets is not None:
            iota = iota + jnp.asarray(offsets[d], _U32)
        h = avalanche(h ^ (iota * _U32(_DIM_PRIMES[prime_offset + d])))
    return h


def _bits_rademacher(bits, dtype):
    sign = 1.0 - 2.0 * (bits >> 31).astype(jnp.float32)
    return sign.astype(dtype)


def _bits_gaussian(h1, dtype):
    h2 = avalanche(h1 ^ _U32(0x68E31DA4))
    # uniforms in (0, 1]: use top 24 bits, add 1 ulp to avoid log(0)
    u1 = ((h1 >> 8).astype(jnp.float32) + 1.0) * (1.0 / 16777216.0)
    u2 = (h2 >> 8).astype(jnp.float32) * (1.0 / 16777216.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = (2.0 * np.pi) * u2
    return (r * jnp.cos(theta)).astype(dtype)


def rademacher_field(seed, salt: int, shape, dtype=jnp.float32, offsets=None,
                     prime_offset: int = 0, base=None):
    """±1 field, one hash per element (default ZO perturbation)."""
    bits = _coord_hash(seed, salt, shape, offsets, prime_offset, base)
    return _bits_rademacher(bits, dtype)


def gaussian_field(seed, salt: int, shape, dtype=jnp.float32, offsets=None,
                   prime_offset: int = 0, base=None):
    """N(0,1) field via Box-Muller on two decorrelated hash fields."""
    h1 = _coord_hash(seed, salt, shape, offsets, prime_offset, base)
    return _bits_gaussian(h1, dtype)


def z_field(seed, salt: int, shape, dtype=jnp.float32, dist: str = "rademacher",
            offsets=None, prime_offset: int = 0, base=None):
    if dist == "rademacher":
        return rademacher_field(seed, salt, shape, dtype, offsets,
                                prime_offset, base)
    if dist == "gaussian":
        return gaussian_field(seed, salt, shape, dtype, offsets,
                              prime_offset, base)
    raise ValueError(f"unknown zo distribution: {dist}")


def z_rows(base, row_ids, n_cols: int, dtype=jnp.float32,
           dist: str = "rademacher", prime_offset: int = 0):
    """z rows of a ``(R, n_cols)`` leaf gathered at ``row_ids``.

    Equivalent to ``z_field(..., (R, n_cols))[row_ids]`` element-for-element
    but never materializes the full table -- this keeps an embedding-table
    perturbation O(tokens * d) instead of O(vocab * d). ``row_ids`` may have
    any shape; the result appends a trailing ``n_cols`` axis.
    """
    h = avalanche(jnp.asarray(base, _U32)
                  ^ (jnp.asarray(row_ids, _U32) * _U32(_DIM_PRIMES[prime_offset])))
    ci = jax.lax.broadcasted_iota(_U32, h.shape + (n_cols,), h.ndim)
    h = avalanche(h[..., None] ^ (ci * _U32(_DIM_PRIMES[prime_offset + 1])))
    if dist == "rademacher":
        return _bits_rademacher(h, dtype)
    if dist == "gaussian":
        return _bits_gaussian(h, dtype)
    raise ValueError(f"unknown zo distribution: {dist}")
