"""int8 gradient compression for the derivative-based (Adam) baseline.

A distributed-optimization trick for the *gradient* arm only: MeZO's
cross-pod traffic is already K scalars per step, so compression there is
moot -- which is precisely the paper's systems advantage at scale.

Per-leaf symmetric int8 quantization with an fp32 absmax scale. Under jit
SPMD the subsequent psum runs over int32-accumulated values; stochastic
rounding keeps the compressed estimator unbiased.

The quantize/dequantize primitives live in :mod:`repro.optim.quant` (one
copy shared with adapter delta compaction and the quantized-base
runtime); this module keeps the gradient-tree roundtrip and re-exports
the helpers for back-compat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.quant import int8_dequantize, int8_quantize  # noqa: F401


def int8_compress_tree(grads):
    """Quantize->dequantize each float leaf (simulates on-the-wire int8).

    Under pjit the psum over the data axis happens on the dequantized
    value; the roundtrip here is what bounds the numerical error, while
    the wire format in a manual shard_map pipeline would ship (q, scale).
    """
    def roundtrip(g):
        if not jnp.issubdtype(g.dtype, jnp.floating) or g.ndim == 0:
            return g
        q, s = int8_quantize(g)
        return int8_dequantize(q, s, g.dtype)
    return jax.tree.map(roundtrip, grads)
