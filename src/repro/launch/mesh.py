"""Production mesh builders. TPU v5e: one pod = 16 x 16 = 256 chips.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init --
the dry-run sets XLA_FLAGS before importing anything).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


# v5e hardware constants used by the roofline analysis (per assignment)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
