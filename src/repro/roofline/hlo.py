"""Loop-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts ``while`` (lax.scan) bodies ONCE --
for a 40-layer scanned transformer that under-reports flops / bytes /
collectives by ~40x. This module parses the optimized, SPMD-partitioned
HLO text and walks the computation graph:

  * dot flops  = 2 * prod(result dims) * prod(contracted dims), descending
    into fusions/calls,
  * collective bytes by kind (all-reduce counted 2x ring traffic),
  * HBM traffic proxy = result bytes of top-level ops, x2 (write + one
    read), NOT descending into fusions (fusion internals stay in
    VMEM/registers),
  * while bodies multiplied by their trip count (from the
    ``known_trip_count`` backend_config, falling back to the largest
    integer constant in the condition computation).

Accuracy: flops are exact for dot-dominated models; the byte proxy is a
~2x-band estimate, clearly labelled in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_TRIP_RE = re.compile(r"known_trip_count\W+n\W+(\d+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


RE_PARAM = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|"
                      r"[\w\[\],]+(?:\{[\d,]*\})?)")


def _norm_types(type_str: str) -> set:
    """Normalized 'dtype[d0,d1]' strings for every array in a type."""
    return {dt + "[" + ",".join(str(x) for x in dims) + "]"
            for dt, dims in _shape_dims(type_str)}


class Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.lines: List[str] = []
        self.symbols: Dict[str, str] = {}   # %name -> type string
        self.param_types: set = set()       # carried-buffer detection
        # header params: "param: (s32[], f32[4,16]), other: f32[8]"
        m = _COMP_HDR_RE.match(header)
        if m:
            for part in re.findall(RE_PARAM, m.group(2)):
                self.symbols[part[0]] = part[1]
                self.param_types |= _norm_types(part[1])


class HloCost:
    __slots__ = ("flops", "coll", "hbm", "hbm_once")

    def __init__(self):
        self.flops = 0.0
        self.coll: Dict[str, float] = defaultdict(float)
        self.hbm = 0.0
        # results shaped like a loop-carried buffer (scan ys-stacking via
        # in-place dynamic-update-slice): real per-trip traffic is one
        # slice, so the full buffer is charged ONCE per loop, not x trips
        self.hbm_once = 0.0

    def add(self, other: "HloCost", mult: float = 1.0,
            hbm_too: bool = True):
        self.flops += mult * other.flops
        for k, v in other.coll.items():
            self.coll[k] += mult * v
        if hbm_too:
            self.hbm += mult * other.hbm + other.hbm_once


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, Computation] = {}
        cur: Optional[Computation] = None
        entry = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and "->" in line and "{" in line:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = Computation(m.group(1), line)
                    self.comps[cur.name] = cur
                    if line.startswith("ENTRY"):
                        entry = cur.name
                    continue
            if cur is not None and line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                cur.lines.append(line)
                d = _DEF_RE.match(line)
                if d:
                    cur.symbols[d.group(1)] = d.group(2)
        self.entry = entry
        self._memo: Dict[Tuple[str, bool], HloCost] = {}

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: Computation, line: str, result_type: str
                   ) -> float:
        rdims = _shape_dims(result_type)
        if not rdims:
            return 0.0
        rn = 1
        for d in rdims[0][1]:
            rn *= d
        # lhs operand name across HLO printer dialects: "dot(%a, ...)",
        # "dot(f32[2,8]{1,0} %a, ...)", sigil-less "dot(Arg_0.1, ...)",
        # and TPU layouts with tiling "dot(f32[8,4]{1,0:T(8,128)} %a, ...)"
        # -- skip an optional leading type token, then an optional '%'
        mo = re.search(
            r"dot\((?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%?([\w.\-]+)", line)
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if not mo or not mc:
            return 2.0 * rn  # degenerate
        lhs_type = comp.symbols.get(mo.group(1), "")
        ldims = _shape_dims(lhs_type)
        if not ldims:
            return 2.0 * rn
        k = 1
        for ci in [int(x) for x in mc.group(1).split(",") if x]:
            if ci < len(ldims[0][1]):
                k *= ldims[0][1][ci]
        return 2.0 * rn * k

    def _trip_count(self, line: str) -> float:
        m = _TRIP_RE.search(line)
        if m:
            return float(m.group(1))
        mc = _COND_RE.search(line)
        if mc and mc.group(1) in self.comps:
            consts = [int(x) for x in re.findall(
                r"constant\((\d+)\)",
                "\n".join(self.comps[mc.group(1)].lines))]
            if consts:
                return float(max(consts))
        return 1.0

    def cost_of(self, name: str, top_level: bool) -> HloCost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        cost = HloCost()
        self._memo[key] = cost  # break cycles
        comp = self.comps.get(name)
        if comp is None:
            return cost
        def _charge(rt):
            b = 2.0 * _type_bytes(rt)
            if _norm_types(rt) & comp.param_types:
                cost.hbm_once += b
            else:
                cost.hbm += b

        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            result_type, op = d.group(2), d.group(3)
            if op == "dot":
                cost.flops += self._dot_flops(comp, line, result_type)
                if top_level:
                    _charge(result_type)
            elif op.rstrip("-start") in _COLL_KINDS or \
                    any(op == k or op == k + "-start" for k in _COLL_KINDS):
                if op.endswith("-done"):
                    continue
                kind = op[:-6] if op.endswith("-start") else op
                cost.coll[kind] += _type_bytes(result_type)
                if top_level:
                    _charge(result_type)
            elif op == "while":
                trips = self._trip_count(line)
                body = _CALLS_RE.search(line)
                if body and body.group(1) in self.comps:
                    cost.add(self.cost_of(body.group(1), top_level),
                             mult=trips)
            elif op in ("fusion", "call", "conditional", "async-start"):
                called = _CALLS_RE.search(line)
                if called and called.group(1) in self.comps:
                    sub = self.cost_of(called.group(1),
                                       top_level and op == "call")
                    cost.add(sub, hbm_too=(op == "call"))
                if top_level:
                    _charge(result_type)
            else:
                if top_level and op not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast"):
                    _charge(result_type)
        return cost

    def total(self) -> HloCost:
        if self.entry is None:
            return HloCost()
        return self.cost_of(self.entry, True)


def analyze(hlo_text: str) -> Dict[str, float]:
    """Loop-aware module costs: flops, hbm_bytes, per-kind + total
    collective bytes (all-reduce 2x)."""
    mod = HloModule(hlo_text)
    c = mod.total()
    coll_total = 0.0
    for k, v in c.coll.items():
        coll_total += 2 * v if k == "all-reduce" else v
    out = {"flops": c.flops, "hbm_bytes": c.hbm,
           "collective_bytes": coll_total}
    for k, v in c.coll.items():
        out[f"coll_{k}"] = v
    return out


# ---------------------------------------------------------------------------
# legacy flat helpers (kept for tests / quick summaries)

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """Flat (not loop-aware) [(kind, result_bytes)] -- one count per
    textual occurrence."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        out.append((m.group(3), _type_bytes(m.group(1) or m.group(2))))
    return out


def collective_bytes(hlo_text: str, loop_aware: bool = True
                     ) -> Dict[str, int]:
    """Per-kind byte totals + 'total' (AR 2x). Loop-aware by default."""
    if loop_aware:
        a = analyze(hlo_text)
        sums = {k[5:]: int(v) for k, v in a.items()
                if k.startswith("coll_")}
        sums["total"] = int(a["collective_bytes"])
        return sums
    sums: Dict[str, int] = defaultdict(int)
    for op, nbytes in parse_collectives(hlo_text):
        sums[op] += nbytes
    total = sum(2 * b if op == "all-reduce" else b
                for op, b in sums.items())
    sums["total"] = total
    return dict(sums)
