"""Fused perturbed-forward path: kernel parity, ctx/salt consistency,
mezo_step_fused equivalence with the sequential and vmapdir strategies."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MezoConfig, PerturbCtx, add_scaled_z, mezo_step,
                        mezo_step_fused, mezo_step_vmapdir, replay_update)
from repro.core import rng as zrng
from repro.data.synthetic import lm_batches, sst2_batches
from repro.kernels import ops, ref
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

# non-square and non-divisible shapes on purpose
MM_SHAPES = [(8, 128, 128), (16, 96, 160), (32, 100, 60), (7, 33, 130)]


def _tiny_model(**overrides):
    kw = dict(n_layers=2, d_model=64, d_ff=128, vocab=128)
    kw.update(overrides)
    cfg = get_config("opt-1.3b").reduced(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in next(lm_batches(2, 16, cfg.vocab, seed=1)).items()}
    return model, params, batch


# ---------------------------------------------------------------------------
# kernel-level parity


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_zo_matmul_interpret_matches_ref(mkn, dist):
    m, k, n = mkn
    x = jax.random.normal(KEY, (m, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), jnp.float32) * 0.1
    got = ops.zo_matmul(x, w, 7, 123, 0.01, dist=dist)
    want = ref.zo_matmul_ref(x, w, jnp.uint32(7), 123, 0.01, dist=dist)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_zo_matmul_prehashed_matches_stacked_slice(dist):
    """Kernel tiles for a layer-slice of a scan-stacked (L, K, N) leaf must
    reproduce the full leaf's z-field (the fused-forward RNG contract)."""
    seed, salt, (L, k, n) = jnp.uint32(11), 4242, (3, 32, 256)
    full_z = zrng.z_field(seed, salt, (L, k, n), dist=dist)
    x = jax.random.normal(KEY, (8, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n), jnp.float32) * 0.1
    for layer in (0, L - 1):
        base = zrng.fold_leading(zrng.leaf_base(seed, salt), layer)
        got = ops.zo_matmul(x, w, base, 0, 0.5, dist=dist,
                            prime_offset=1, prehashed=True)
        want = x @ (w + 0.5 * full_z[layer])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_rank0_slice_matches_stacked_vector(dist):
    """Slicing a stacked (L,) leaf down to a scalar must reproduce the
    full vector field -- no extra avalanche on the rank-0 path."""
    seed, salt = jnp.uint32(11), 4242
    full = zrng.z_field(seed, salt, (5,), dist=dist)
    for layer in range(5):
        base = zrng.fold_leading(zrng.leaf_base(seed, salt), layer)
        got = zrng.z_field(None, 0, (), dist=dist, prime_offset=1, base=base)
        np.testing.assert_array_equal(np.asarray(full[layer]),
                                      np.asarray(got))


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_z_rows_matches_field_gather(dist):
    seed, salt = jnp.uint32(5), 99
    full = zrng.z_field(seed, salt, (64, 48), dist=dist)
    ids = jnp.array([[0, 63, 7], [5, 5, 31]])
    got = zrng.z_rows(zrng.leaf_base(seed, salt), ids, 48, dist=dist)
    np.testing.assert_array_equal(np.asarray(full)[np.asarray(ids)],
                                  np.asarray(got))


# ---------------------------------------------------------------------------
# ctx-forward consistency: the fused loss must see exactly the z-fields
# add_scaled_z applies to the stacked parameter tree (salt/path contract)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("arch", ["opt-1.3b", "qwen3-4b",
                                  "granite-moe-1b-a400m"])
def test_ctx_forward_matches_perturbed_params(arch, dist):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in next(lm_batches(2, 16, cfg.vocab, seed=1)).items()}
    seed, eps = jnp.uint32(9), jnp.float32(1e-3)
    la = float(model.loss(add_scaled_z(params, seed, eps, dist=dist), batch))
    lb = float(model.loss(params, batch,
                          perturb=PerturbCtx(seed=seed, coeff=eps, dist=dist)))
    np.testing.assert_allclose(la, lb, rtol=1e-6, atol=1e-6)


def test_ctx_forward_matches_perturbed_params_cls():
    cfg = get_config("roberta-large").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in next(sst2_batches(2, 16, cfg.vocab, seed=1)).items()}
    seed, eps = jnp.uint32(4), jnp.float32(1e-3)
    la = float(model.loss(add_scaled_z(params, seed, eps), batch))
    lb = float(model.loss(params, batch,
                          perturb=PerturbCtx(seed=seed, coeff=eps)))
    np.testing.assert_allclose(la, lb, rtol=1e-6, atol=1e-6)


def test_ctx_kernel_path_matches_jnp_path():
    """use_kernel=True routes MXU-aligned projections through the Pallas
    kernel (interpret mode here) -- values must match the jnp fallback."""
    model, params, batch = _tiny_model(d_model=128, d_ff=256, vocab=256)
    ctx = PerturbCtx(seed=jnp.uint32(3), coeff=jnp.float32(1e-3))
    lj = float(model.loss(params, batch, perturb=ctx))
    lk = float(model.loss(params, batch,
                          perturb=dataclasses.replace(ctx, use_kernel=True)))
    np.testing.assert_allclose(lj, lk, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# step-level equivalence


def test_fused_step_matches_vmapdir_tight():
    model, params, batch = _tiny_model()
    mcfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=3)
    pf, auxf = mezo_step_fused(model.loss, jax.tree.map(jnp.copy, params),
                               batch, jnp.uint32(7), mcfg)
    pv, auxv = mezo_step_vmapdir(model.loss, jax.tree.map(jnp.copy, params),
                                 batch, jnp.uint32(7), mcfg)
    np.testing.assert_allclose(np.asarray(auxf.gs), np.asarray(auxv.gs),
                               rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pv)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_fused_step_matches_sequential():
    """Acceptance: fused params bit-comparable (f32 tol <= 1e-5) with the
    sequential walk on a tiny transformer."""
    model, params, batch = _tiny_model()
    mcfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=3)
    pf, auxf = mezo_step_fused(model.loss, jax.tree.map(jnp.copy, params),
                               batch, jnp.uint32(7), mcfg)
    ps, auxs = mezo_step(model.loss, jax.tree.map(jnp.copy, params),
                         batch, jnp.uint32(7), mcfg)
    np.testing.assert_allclose(float(auxf.loss), float(auxs.loss),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(ps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_fused_step_replay_bit_exact():
    """Fused updates apply to the pristine base point, so the (seed, gs)
    replay log reconstructs them bit-for-bit (checkpointer contract)."""
    model, params, batch = _tiny_model()
    mcfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    pf, aux = mezo_step_fused(model.loss, jax.tree.map(jnp.copy, params),
                              batch, jnp.uint32(13), mcfg)
    pr = replay_update(jax.tree.map(jnp.copy, params), aux.seed, aux.gs, mcfg)
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_step_descends():
    model, params, batch = _tiny_model()
    mcfg = MezoConfig(eps=1e-2, lr=5e-3, n_directions=4)
    p = jax.tree.map(jnp.copy, params)
    losses = []
    for t in range(30):
        p, aux = mezo_step_fused(model.loss, p, batch, jnp.uint32(t), mcfg)
        losses.append(float(aux.loss))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["rwkv6-7b", "jamba-v0.1-52b",
                                  "whisper-base"])
def test_fused_step_matches_vmapdir_all_families(arch):
    """The block-registry runtime threads PerturbCtx through every
    family, so the fused estimator's projected gradients match vmapdir's
    (which perturbs the whole tree) on hybrid / rwkv6 / encdec too --
    the three families that used to take a transient materialize copy."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in next(lm_batches(2, 16, cfg.vocab, seed=1)).items()}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (2, cfg.enc_len, cfg.d_model))
    mcfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    pf, auxf = mezo_step_fused(model.loss, jax.tree.map(jnp.copy, params),
                               batch, jnp.uint32(5), mcfg)
    pv, auxv = mezo_step_vmapdir(model.loss, jax.tree.map(jnp.copy, params),
                                 batch, jnp.uint32(5), mcfg)
    np.testing.assert_allclose(np.asarray(auxf.gs), np.asarray(auxv.gs),
                               rtol=1e-6, atol=1e-7)
