"""roberta-large (paper's own model, Sec 4.1: fine-tuned on SST-2)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="roberta-large", family="encoder", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab=50265,
        act="gelu", norm="layernorm", pos="learned", causal=False,
        n_classes=2, max_seq=512, dtype="float32")
