"""Parameter / activation sharding rules for the (pod, data, model) mesh.

Megatron-style tensor parallelism over the ``model`` axis:

  * embeddings + lm_head: vocab-sharded,
  * attention: head axis sharded (wq/wk/wv column-, wo row-parallel),
  * MLP: w_in column-, w_out row-parallel,
  * MoE: the *expert* axis sharded (expert parallelism); router replicated,
  * mamba/rwkv: d_inner / channel projections column/row-sharded,
  * norms/scalars: replicated.

Params are replicated across ``pod`` and ``data`` (ZO direction
parallelism needs no param sharding across pods -- cross-pod traffic is
scalars only; see DESIGN.md Sec 4).

Rules are matched on the flattened path string, most-specific-first.
``spec_tree(params_shape_tree)`` returns a PartitionSpec pytree suitable
for jax.jit in_shardings / ShapeDtypeStruct sharding.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# (path regex, spec builder given leaf ndim). Leading scan axis (stacked
# blocks) is detected by ndim relative to the rule's base rank.
_RULES = [
    # embeddings: vocab-sharded
    (r"embed/tok$", lambda nd: P("model", None)),
    (r"embed/pos$", lambda nd: P(None, None)),
    (r"lm_head/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"cls_head/w$", lambda nd: P(None, None)),
    # attention
    (r"(attn|self|cross)/wq/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"(attn|self|cross)/wk/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"(attn|self|cross)/wv/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"(attn|self|cross)/wo/w$", lambda nd: _stk(nd, 2, P("model", None))),
    (r"(attn|self|cross)/w[qkv]/b$", lambda nd: _stk(nd, 1, P("model"))),
    (r"(attn|self|cross)/wo/b$", lambda nd: _stk(nd, 1, P(None))),
    # dense MLPs (incl. moe shared expert). Gated w_in uses the
    # interleaved (D, F, 2) layout (see layers.mlp_init): shard F.
    (r"(mlp|shared)/w_in/w$", lambda nd: _gated_or_flat_in(nd)),
    (r"(mlp|shared)/w_out/w$", lambda nd: _stk(nd, 2, P("model", None))),
    (r"(mlp|shared)/w_in/b$", lambda nd: _stk(nd, 1, P("model"))),
    (r"(mlp|shared)/w_out/b$", lambda nd: _stk(nd, 1, P(None))),
    # MoE: expert-parallel over the expert axis
    (r"moe/router$", lambda nd: _stk(nd, 2, P(None, None))),
    # w_in: flat (E, D, F) or gated-interleaved (E, D, F, 2), +stack axis
    (r"moe/w_in$", lambda nd: _stk(nd, 3, P("model", None, None))
     or _stk(nd - 1, 3, P("model", None, None, None))),
    (r"moe/w_out$", lambda nd: _stk(nd, 3, P("model", None, None))),
]

# fsdp_params=True: expert weights additionally sharded over ``data`` on
# the per-expert hidden dim (storage), gathered per layer inside the EP
# shard_map (ZeRO-3 style). Required when params/chip exceeds HBM with
# model-only sharding (kimi-k2: 2 TB expert weights -> 8 GB/chip in 2-D).
_FSDP_RULES = [
    (r"moe/w_in$", lambda nd: _stk(nd, 3, P("model", None, "data"))
     or _stk(nd - 1, 3, P("model", None, "data", None))),
    (r"moe/w_out$", lambda nd: _stk(nd, 3, P("model", "data", None))),
]

_RULES += [
    # mamba
    (r"mamba/in_proj/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"mamba/out_proj/w$", lambda nd: _stk(nd, 2, P("model", None))),
    (r"mamba/(conv_w|conv_b|x_proj/w|dt_proj/w|dt_proj/b|A_log|D)",
     lambda nd: None),  # replicate small SSM innards
    # rwkv6
    (r"tm/w[rkvg]/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"tm/wo/w$", lambda nd: _stk(nd, 2, P("model", None))),
    (r"cm/wk/w$", lambda nd: _stk(nd, 2, P(None, "model"))),
    (r"cm/wv/w$", lambda nd: _stk(nd, 2, P("model", None))),
    (r"cm/wr/w$", lambda nd: _stk(nd, 2, P(None, None))),
]


def maybe_shard(x, *spec):
    """with_sharding_constraint iff an ambient mesh with the named axes is
    active (jax.set_mesh). No-op in mesh-less CPU smoke tests, so model
    code can annotate activations unconditionally."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or getattr(am, "empty", True):
        return x
    names = set(am.axis_names or ())
    if any(a not in names for a in jax.tree.leaves(list(spec))
           if isinstance(a, str)):
        return x
    # drop axes that don't divide the dim
    fixed = []
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    for d, a in enumerate(spec):
        if a is None:
            fixed.append(None)
            continue
        axes = (a,) if isinstance(a, str) else tuple(a)
        prod = 1
        keep = []
        for ax in axes:
            if x.shape[d] % (prod * sizes[ax]) == 0:
                keep.append(ax)
                prod *= sizes[ax]
        fixed.append(tuple(keep) if len(keep) > 1 else
                     (keep[0] if keep else None))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def _gated_or_flat_in(nd: int, shape=None):
    # rank 2 = ungated (D, F); rank 3 = gated (D, F, 2) OR stacked
    # ungated (L, D, F), told apart by the trailing dim of 2;
    # rank 4 = stacked gated (L, D, F, 2).
    if nd == 2:
        return P(None, "model")
    if nd == 3:
        if shape is not None and shape[-1] == 2:
            return P(None, "model", None)      # gated (D, F, 2)
        return P(None, None, "model")          # stacked ungated (L, D, F)
    if nd == 4:
        return P(None, None, "model", None)
    return None


def _stk(nd: int, base: int, spec: P):
    """Prepend None for a stacked scan axis when leaf rank = base+1."""
    if nd == base:
        return spec
    if nd == base + 1:
        return P(None, *spec)
    return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def leaf_spec(path: str, ndim: int, shape=None, fsdp: bool = False) -> P:
    rules = (_FSDP_RULES + _RULES) if fsdp else _RULES
    for pat, fn in rules:
        if re.search(pat, path):
            try:
                s = fn(ndim, shape)
            except TypeError:
                s = fn(ndim)
            if s is not None:
                return s
            break
    return P()  # replicate


def spec_tree(params: PyTree, fsdp: bool = False,
              use_tp: bool = True) -> PyTree:
    """PartitionSpec pytree for a params (or ShapeDtypeStruct) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    if not use_tp:   # small models: replicate weights, pure DP
        specs = [P() for _ in leaves]
    else:
        specs = [leaf_spec(_path_str(p), l.ndim, tuple(l.shape), fsdp)
                 for p, l in leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def fit_spec(shape, spec: P, mesh) -> P:
    """Drop sharded axes that do not evenly divide their dim (replicate
    instead) -- e.g. odd vocab sizes like granite's 49155."""
    fixed = []
    for d, entry in enumerate(spec):
        if entry is None:
            fixed.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        keep, prod = [], 1
        for ax in axes:
            if ax not in mesh.axis_names:
                continue
            sz = _axis_size(mesh, ax)
            if shape[d] % (prod * sz) == 0:
                keep.append(ax)
                prod *= sz
        fixed.append(tuple(keep) if len(keep) > 1 else
                     (keep[0] if keep else None))
    return P(*fixed)


def fit_specs(tree: PyTree, specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda l, s: fit_spec(l.shape, s, mesh), tree, specs)


def sharding_tree(params: PyTree, mesh) -> PyTree:
    specs = fit_specs(params, spec_tree(params), mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# batch / cache shardings (shape- and mesh-aware: axes that do not divide
# a dim are dropped rather than producing an invalid sharding)


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _fit(mesh, dim: int, *names):
    """Largest prefix of ``names`` whose product divides ``dim``."""
    chosen = []
    prod = 1
    for n in names:
        if n is None or n not in mesh.axis_names:
            continue
        sz = _axis_size(mesh, n)
        if dim % (prod * sz) == 0:
            chosen.append(n)
            prod *= sz
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def batch_spec(batch_like: PyTree, mesh, data_axes=("data",)) -> PyTree:
    """Shard the leading (batch) dim of every batch leaf over data axes."""
    def spec(l):
        if l.ndim == 0:
            return P()
        return P(_fit(mesh, l.shape[0], *data_axes),
                 *(None,) * (l.ndim - 1))
    return jax.tree.map(spec, batch_like)


# cache leaf name -> (dims meaning). KV caches shard *sequence* over the
# model axis (sequence-parallel cache: kv_heads are too few to shard
# 16-way and the cache dominates decode memory; attention over the
# sharded axis lowers to a partial-softmax combine).
_CACHE_LAYOUTS = {
    # name: (batch_dim, seq_dim, model_dim). Every unified StateCache
    # leaf is (n_layers, B, ...) -- batch always dim 1 (models/runtime).
    "k": (1, 2, None), "v": (1, 2, None),
    "xk": (1, None, None), "xv": (1, None, None),
    "conv": (1, None, None),          # (nb, B, w, di)
    "ssm": (1, None, 2),              # (nb, B, di, n): di over model
    "state": (1, 2, None),            # (L, B, H, hd, hd): H over model
    "x_prev": (1, None, None),        # rwkv token-shift buffers
    # paged KV pools (L, n_pages, page_size, KV, hd): no batch axis --
    # slots address the shared pool through a page table, so shard the
    # page axis the way dense K/V shards its sequence axis
    "k_pages": (None, 1, None), "v_pages": (None, 1, None),
}


def cache_spec(cache_like: PyTree, mesh) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    out = []
    for path, l in leaves:
        name = str(getattr(path[-1], "key", path[-1]))
        bd, sd, md = _CACHE_LAYOUTS.get(name, (None, None, None))
        spec = [None] * l.ndim
        if bd is not None and bd < l.ndim:
            spec[bd] = _fit(mesh, l.shape[bd], "data")
        if sd is not None and sd < l.ndim:
            # sequence (or page/head) axis over model; spill onto data
            # when no batch axis is using it (long-context batch=1
            # decode, or a pool leaf with no batch axis at all)
            if bd is None or spec[bd] is None:
                spec[sd] = _fit(mesh, l.shape[sd], "model", "data")
            else:
                spec[sd] = _fit(mesh, l.shape[sd], "model")
        if md is not None and md < l.ndim:
            spec[md] = _fit(mesh, l.shape[md], "model")
        out.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, out)
