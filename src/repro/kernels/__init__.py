"""Pallas TPU kernels for the ZO hot spots (validated in interpret mode).

zo_add    : W + c*z(seed)        -- perturb / fused restore+update sweep
zo_matmul : X @ (W + c*z(seed))  -- perturbed forward matmul, z never in HBM
"""

from repro.kernels import ops, ref
from repro.kernels.ops import zo_add, zo_matmul

__all__ = ["ops", "ref", "zo_add", "zo_matmul"]
