"""Model assembly for every assigned architecture family.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions:

  init(key)                          -> params
  forward(params, batch)             -> logits          (train / prefill)
  loss(params, batch)                -> scalar          (the ZO objective)
  init_cache(bsz)                    -> decode cache pytree
  decode_step(params, cache, tok, pos) -> (logits, cache)
  prefill(params, cache, prompt)     -> (logits, cache)  (fused, optional)

``prefill`` runs a whole (B, P) prompt in ONE call, writing cache
positions [0, P) and returning the next-token logits (B, 1, V) -- the
serving engine's replacement for P per-token ``decode_step`` dispatches.
Families without a wired prefill leave it ``None`` (the engine falls
back to the per-token loop). ``decode_step`` accepts ``pos`` as a scalar
(whole batch at one position) or as a (B,) vector (continuous batching:
every slot decodes at its own position).

Layer stacks are ``lax.scan``-ed over stacked (L, ...) params so the HLO
is O(1) in depth -- essential for compiling 61-layer 1T-param configs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv6 as R
from repro.models.config import ModelConfig

PyTree = Any
AUX_LOSS_WEIGHT = 0.01


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Callable
    prefill: Optional[Callable] = None


# ===========================================================================
# decoder-only LM (dense / moe / vlm-backbone)


def _lm_block_init(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln_attn": L.norm_init(cfg, k1), "attn": L.attn_init(cfg, k2),
         "ln_ffn": L.norm_init(cfg, k3)}
    if cfg.n_experts:
        p["moe"] = MoE.moe_init(cfg, k4)
    else:
        p["mlp"] = L.mlp_init(cfg, k4)
    return p


def _lm_block_apply(cfg, p, x, *, positions, kv_mask=None, ctx=None):
    x = x + L.attn_apply(cfg, p["attn"],
                         L.norm_apply(cfg, p["ln_attn"], x,
                                      _sub(ctx, "ln_attn")),
                         positions=positions, kv_mask=kv_mask,
                         ctx=_sub(ctx, "attn"))
    h = L.norm_apply(cfg, p["ln_ffn"], x, _sub(ctx, "ln_ffn"))
    if cfg.n_experts:
        fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
        moe_p = p["moe"] if ctx is None else ctx.materialize(p["moe"], "moe")
        y, aux = fn(cfg, moe_p, h)
    else:
        y, aux = L.mlp_apply(cfg, p["mlp"], h, _sub(ctx, "mlp")), \
            jnp.float32(0.0)
    return x + y, aux


def _lm_init(cfg, key):
    ke, kb, kn, kh = jax.random.split(key, 4)
    blocks = jax.vmap(lambda k: _lm_block_init(cfg, k))(
        jax.random.split(kb, cfg.n_layers))
    p = {"embed": L.embed_init(cfg, ke), "blocks": blocks,
         "ln_f": L.norm_init(cfg, kn)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))
    if cfg.n_classes:
        p["cls_head"] = L.dense_init(kh, cfg.d_model, cfg.n_classes,
                                     jnp.float32, bias=True)
    return p


def _lm_backbone(cfg, params, x, positions, kv_mask=None, ctx=None):
    def body(carry, xs):
        bp, li = xs
        h, aux = carry
        # block leaves are scan-stacked (L, ...): the perturb ctx binds the
        # layer index so per-layer z slices match the stacked leaf's field
        bctx = None if ctx is None else ctx.scope("blocks").at_layer(li)
        h, a = _lm_block_apply(cfg, bp, h, positions=positions,
                               kv_mask=kv_mask, ctx=bctx)
        return (h, aux + a), None

    n_layers = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["blocks"], jnp.arange(n_layers, dtype=jnp.uint32)))
    return L.norm_apply(cfg, params["ln_f"], x, _sub(ctx, "ln_f")), aux


def _lm_forward(cfg, params, batch, last_only=False, perturb=None):
    tokens = batch["tokens"]
    x = L.embed_apply(cfg, params["embed"], tokens,
                      ctx=_sub(perturb, "embed"))
    n_prefix = 0
    if "patch_embeds" in batch:                    # vlm: prepend stub patches
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        n_prefix = batch["patch_embeds"].shape[1]
    positions = jnp.arange(x.shape[1])[None]
    kv_mask = batch.get("attn_mask")
    x, aux = _lm_backbone(cfg, params, x, positions, kv_mask, ctx=perturb)
    if n_prefix:
        x = x[:, n_prefix:]
    if last_only:          # prefill: only the next-token logits are needed
        x = x[:, -1:]
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x,
                       ctx=perturb)
    return logits, aux


def softmax_xent(logits, targets, mask=None):
    """Cross entropy that never materializes an f32 copy of the logits.

    Two measured pathologies avoided (EXPERIMENTS.md Sec Perf):
      * ``take_along_axis`` on vocab-sharded logits all-gathers the full
        logits across the model axis -- replaced by a one-hot masked sum
        (local + tiny psum);
      * upcasting logits to f32 with multiple consumers (lse AND gold)
        writes a full f32 logits tensor to HBM (12.9 GB/chip/pass on
        granite train_4k) -- instead, max/gold read the bf16 logits and
        the f32 exp-sum is a single-consumer fusion into its reduce.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    sumexp = jnp.sum(
        jnp.exp((logits - m[..., None]).astype(jnp.float32)), axis=-1)
    lse = m.astype(jnp.float32) + jnp.log(sumexp)
    gold = jnp.sum(
        jnp.where(jnp.arange(logits.shape[-1]) == targets[..., None],
                  logits, jnp.zeros((), logits.dtype)),
        axis=-1).astype(jnp.float32)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-9)
    return jnp.mean(nll)


def _lm_loss(cfg, params, batch, perturb=None):
    """The ZO objective. ``perturb`` (a PerturbCtx) switches on the fused
    perturbed forward: params stay untouched, every weight use applies
    coeff*z in place (see core/perturb_ctx.py)."""
    if cfg.n_classes:                                 # roberta/SST-2 path
        logits, aux = _cls_forward(cfg, params, batch, perturb=perturb)
        return softmax_xent(logits, batch["label"])
    logits, aux = _lm_forward(cfg, params, batch, perturb=perturb)
    ce = softmax_xent(logits, batch["targets"], batch.get("loss_mask"))
    return ce + AUX_LOSS_WEIGHT * aux


def _cls_forward(cfg, params, batch, last_only=False, perturb=None):
    """Encoder classification (roberta): CLS pooling + head.

    last_only is accepted for signature parity with the other family
    forwards (launch/dryrun calls model.forward(..., last_only=True)
    generically) and ignored: CLS logits have no sequence axis."""
    tokens = batch["tokens"]
    x = L.embed_apply(cfg, params["embed"], tokens,
                      ctx=_sub(perturb, "embed"))
    positions = jnp.arange(x.shape[1])[None]
    x, _ = _lm_backbone(cfg, params, x, positions, batch.get("attn_mask"),
                        ctx=perturb)
    cls = x[:, 0].astype(jnp.float32)
    return L.dense(params["cls_head"], jnp.tanh(cls),
                   _sub(perturb, "cls_head")), jnp.float32(0.0)


def _lm_init_cache(cfg, bsz, max_len, dtype):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, bsz, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _decode_attn(cfg, p, x, ck, cv, pos):
    """One-token attention against a (B, S_max, KV, hd) cache layer.

    ``pos`` is a scalar (the whole batch decodes at one position) or a
    (B,) vector (continuous batching: each slot at its own position)."""
    b = x.shape[0]
    pos = jnp.asarray(pos)
    q, k, v = L.attn_project_qkv(cfg, p, x)       # (B,1,H,hd),(B,1,KV,hd)
    if cfg.pos == "rope":
        pos_b = pos[:, None] if pos.ndim else jnp.full((b, 1), pos)
        cs = L.rope_cos_sin(pos_b, cfg.resolved_head_dim,
                            cfg.rope_pct, cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    if pos.ndim:
        def upd(c, u, p_):
            return jax.lax.dynamic_update_slice(c, u, (p_, 0, 0))
        ck = jax.vmap(upd)(ck, k.astype(ck.dtype), pos)
        cv = jax.vmap(upd)(cv, v.astype(cv.dtype), pos)
        valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
    else:
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        valid = (jnp.arange(ck.shape[1]) <= pos)[None, :]
    out = L.attention(q, ck, cv, causal=False, kv_mask=valid, chunk=0)
    return L.dense(p["wo"], out.reshape(b, 1, -1)), ck, cv


def _decode_positions(pos):
    """Learned-pos embedding indices for a scalar or per-slot pos."""
    pos = jnp.asarray(pos)
    return pos[:, None] if pos.ndim else jnp.full((1,), pos)


def _lm_decode_step(cfg, params, cache, tokens, pos):
    """tokens: (B, 1) -> logits (B, 1, V); cache updated at ``pos``."""
    x = L.embed_apply(cfg, params["embed"], tokens,
                      positions=_decode_positions(pos))

    def body(h, xs):
        bp, ck, cv = xs
        a, ck, cv = _decode_attn(cfg, bp["attn"],
                                 L.norm_apply(cfg, bp["ln_attn"], h), ck, cv,
                                 pos)
        h = h + a
        f = L.norm_apply(cfg, bp["ln_ffn"], h)
        if cfg.n_experts:
            fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
            y, _ = fn(cfg, bp["moe"], f)
        else:
            y = L.mlp_apply(cfg, bp["mlp"], f)
        return h + y, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"]))
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"k": ck, "v": cv}


def _prefill_attn(cfg, p, x, ck, cv, positions):
    """Full-prompt attention that also writes positions [0, S) of a
    (B, S_max, KV, hd) cache layer -- causal masking keeps every prompt
    token's view identical to the per-token decode loop's."""
    b, s, _ = x.shape
    q, k, v = L.attn_project_qkv(cfg, p, x)
    if cfg.pos == "rope":
        cs = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_pct,
                            cfg.rope_theta)
        q, k = L.apply_rope(q, cs), L.apply_rope(k, cs)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
    out = L.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    return L.dense(p["wo"], out.reshape(b, s, -1)), ck, cv


def _lm_prefill(cfg, params, cache, tokens):
    """Fused prefill: one jitted call over the whole (B, P) prompt writes
    cache positions [0, P) and returns next-token logits (B, 1, V) --
    P decode_step dispatches collapsed into one layer-scan."""
    x = L.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None]

    def body(h, xs):
        bp, ck, cv = xs
        a, ck, cv = _prefill_attn(cfg, bp["attn"],
                                  L.norm_apply(cfg, bp["ln_attn"], h),
                                  ck, cv, positions)
        h = h + a
        f = L.norm_apply(cfg, bp["ln_ffn"], h)
        if cfg.n_experts:
            fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
            y, _ = fn(cfg, bp["moe"], f)
        else:
            y = L.mlp_apply(cfg, bp["mlp"], f)
        return h + y, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"]))
    x = L.norm_apply(cfg, params["ln_f"], x[:, -1:])
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"k": ck, "v": cv}


# ===========================================================================
# hybrid (jamba): super-blocks of [mamba x7 + attn], FFN after each sublayer


def _hybrid_block_init(cfg, key):
    nb = cfg.block_len
    ks = jax.random.split(key, 2 * nb)
    p = {}
    for i in range(nb):
        sub = {"ln": L.norm_init(cfg, ks[2 * i])}
        if i == cfg.attn_index:
            sub["attn"] = L.attn_init(cfg, ks[2 * i + 1])
        else:
            sub["mamba"] = M.mamba_init(cfg, ks[2 * i + 1])
        kf = jax.random.fold_in(ks[2 * i + 1], 7)
        sub["ln_ffn"] = L.norm_init(cfg, jax.random.fold_in(kf, 1))
        if cfg.n_experts and i % 2 == 1:
            sub["moe"] = MoE.moe_init(cfg, kf)
        else:
            sub["mlp"] = L.mlp_init(cfg, kf)
        p[f"sub_{i}"] = sub
    return p


def _hybrid_block_apply(cfg, p, x, positions):
    aux = jnp.float32(0.0)
    for i in range(cfg.block_len):
        sub = p[f"sub_{i}"]
        h = L.norm_apply(cfg, sub["ln"], x)
        if i == cfg.attn_index:
            x = x + L.attn_apply(cfg, sub["attn"], h, positions=positions)
        else:
            x = x + M.mamba_apply(cfg, sub["mamba"], h)
        f = L.norm_apply(cfg, sub["ln_ffn"], x)
        if "moe" in sub:
            fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
            y, a = fn(cfg, sub["moe"], f)
            aux = aux + a
        else:
            y = L.mlp_apply(cfg, sub["mlp"], f)
        x = x + y
    return x, aux


def _hybrid_init(cfg, key):
    nb = cfg.n_layers // cfg.block_len
    ke, kb, kn, kh = jax.random.split(key, 4)
    blocks = jax.vmap(lambda k: _hybrid_block_init(cfg, k))(
        jax.random.split(kb, nb))
    return {"embed": L.embed_init(cfg, ke), "blocks": blocks,
            "ln_f": L.norm_init(cfg, kn),
            "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))}


def _hybrid_forward(cfg, params, batch, last_only=False):
    tokens = batch["tokens"]
    x = L.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(x.shape[1])[None]

    def body(carry, bp):
        h, aux = carry
        h, a = _hybrid_block_apply(cfg, bp, h, positions)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = L.norm_apply(cfg, params["ln_f"], x)
    if last_only:
        x = x[:, -1:]
    return L.unembed(cfg, params["embed"], params.get("lm_head"), x), aux


def _hybrid_loss(cfg, params, batch, perturb=None):
    # no fused forward wired for mamba mixers yet: one transient perturbed
    # copy (the vmapdir memory profile), still zero walk sweeps
    if perturb is not None:
        params = perturb.materialize(params)
    logits, aux = _hybrid_forward(cfg, params, batch)
    return softmax_xent(logits, batch["targets"], batch.get("loss_mask")) \
        + AUX_LOSS_WEIGHT * aux


def _hybrid_init_cache(cfg, bsz, max_len, dtype):
    nb = cfg.n_layers // cfg.block_len
    hd = cfg.resolved_head_dim
    di = cfg.mamba_expand * cfg.d_model
    n_mamba = cfg.block_len - 1
    return {
        "k": jnp.zeros((nb, bsz, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((nb, bsz, max_len, cfg.n_kv_heads, hd), dtype),
        "conv": jnp.zeros((nb, n_mamba, bsz, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((nb, n_mamba, bsz, di, cfg.mamba_d_state),
                         jnp.float32),
    }


def _hybrid_decode_step(cfg, params, cache, tokens, pos):
    x = L.embed_apply(cfg, params["embed"], tokens)

    def body(h, xs):
        bp, ck, cv, conv, ssm = xs
        new_conv, new_ssm = [], []
        mi = 0
        for i in range(cfg.block_len):
            sub = bp[f"sub_{i}"]
            z = L.norm_apply(cfg, sub["ln"], h)
            if i == cfg.attn_index:
                a, ck, cv = _decode_attn(cfg, sub["attn"], z, ck, cv, pos)
                h = h + a
            else:
                st = {"conv": conv[mi], "ssm": ssm[mi]}
                y, st = M.mamba_step(cfg, sub["mamba"], st, z)
                new_conv.append(st["conv"])
                new_ssm.append(st["ssm"])
                h = h + y
                mi += 1
            f = L.norm_apply(cfg, sub["ln_ffn"], h)
            if "moe" in sub:
                fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
                y, _ = fn(cfg, sub["moe"], f)
            else:
                y = L.mlp_apply(cfg, sub["mlp"], f)
            h = h + y
        return h, (ck, cv, jnp.stack(new_conv), jnp.stack(new_ssm))

    x, (ck, cv, conv, ssm) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["conv"],
                  cache["ssm"]))
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"k": ck, "v": cv, "conv": conv, "ssm": ssm}


def _hybrid_prefill(cfg, params, cache, tokens):
    """Fused prefill for the hybrid family: attention sublayers write the
    KV cache, mamba sublayers roll (conv, ssm) state to the last token."""
    x = L.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None]

    def body(h, xs):
        bp, ck, cv, conv, ssm = xs
        new_conv, new_ssm = [], []
        mi = 0
        for i in range(cfg.block_len):
            sub = bp[f"sub_{i}"]
            z = L.norm_apply(cfg, sub["ln"], h)
            if i == cfg.attn_index:
                a, ck, cv = _prefill_attn(cfg, sub["attn"], z, ck, cv,
                                          positions)
                h = h + a
            else:
                st = {"conv": conv[mi], "ssm": ssm[mi]}
                y, st = M.mamba_prefill(cfg, sub["mamba"], st, z)
                new_conv.append(st["conv"])
                new_ssm.append(st["ssm"])
                h = h + y
                mi += 1
            f = L.norm_apply(cfg, sub["ln_ffn"], h)
            if "moe" in sub:
                fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
                y, _ = fn(cfg, sub["moe"], f)
            else:
                y = L.mlp_apply(cfg, sub["mlp"], f)
            h = h + y
        return h, (ck, cv, jnp.stack(new_conv), jnp.stack(new_ssm))

    x, (ck, cv, conv, ssm) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["conv"],
                  cache["ssm"]))
    x = L.norm_apply(cfg, params["ln_f"], x[:, -1:])
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"k": ck, "v": cv, "conv": conv, "ssm": ssm}


# ===========================================================================
# ssm (rwkv6)


def _rwkv_init(cfg, key):
    ke, kb, kn, kh = jax.random.split(key, 4)

    def block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"ln1": L.norm_init(cfg, k1), "tm": R.timemix_init(cfg, k2),
                "ln2": L.norm_init(cfg, k3), "cm": R.channelmix_init(cfg, k4)}

    blocks = jax.vmap(block)(jax.random.split(kb, cfg.n_layers))
    return {"embed": L.embed_init(cfg, ke), "blocks": blocks,
            "ln_f": L.norm_init(cfg, kn),
            "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))}


def _rwkv_forward(cfg, params, batch, last_only=False):
    x = L.embed_apply(cfg, params["embed"], batch["tokens"])

    def body(h, bp):
        y, _ = R.timemix_apply(cfg, bp["tm"], L.norm_apply(cfg, bp["ln1"], h))
        h = h + y
        y, _ = R.channelmix_apply(cfg, bp["cm"],
                                  L.norm_apply(cfg, bp["ln2"], h))
        return h + y, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.norm_apply(cfg, params["ln_f"], x)
    if last_only:
        x = x[:, -1:]
    return L.unembed(cfg, params["embed"], params.get("lm_head"), x), \
        jnp.float32(0.0)


def _rwkv_loss(cfg, params, batch, perturb=None):
    if perturb is not None:           # transient copy; see _hybrid_loss
        params = perturb.materialize(params)
    logits, _ = _rwkv_forward(cfg, params, batch)
    return softmax_xent(logits, batch["targets"], batch.get("loss_mask"))


def _rwkv_init_cache(cfg, bsz, max_len, dtype):
    h, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    ll = cfg.n_layers
    return {
        "tm_state": jnp.zeros((ll, bsz, h, hd, hd), jnp.float32),
        "tm_x": jnp.zeros((ll, bsz, 1, cfg.d_model), dtype),
        "cm_x": jnp.zeros((ll, bsz, 1, cfg.d_model), dtype),
    }


def _rwkv_decode_step(cfg, params, cache, tokens, pos):
    x = L.embed_apply(cfg, params["embed"], tokens)

    def body(h, xs):
        bp, st, tx, cx = xs
        y, (st, tx) = R.timemix_apply(cfg, bp["tm"],
                                      L.norm_apply(cfg, bp["ln1"], h),
                                      state=st, x_prev=tx)
        h = h + y
        y, cx = R.channelmix_apply(cfg, bp["cm"],
                                   L.norm_apply(cfg, bp["ln2"], h), x_prev=cx)
        return h + y, (st, tx, cx)

    x, (st, tx, cx) = jax.lax.scan(
        body, x, (params["blocks"], cache["tm_state"], cache["tm_x"],
                  cache["cm_x"]))
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"tm_state": st, "tm_x": tx, "cm_x": cx}


def _rwkv_prefill(cfg, params, cache, tokens):
    """Fused prefill for rwkv6: the full-sequence WKV scan started from
    the cache state -- arithmetic-identical to per-token decode (the
    recurrence is the same cell either way)."""
    x = L.embed_apply(cfg, params["embed"], tokens)

    def body(h, xs):
        bp, st, tx, cx = xs
        y, (st, tx) = R.timemix_apply(cfg, bp["tm"],
                                      L.norm_apply(cfg, bp["ln1"], h),
                                      state=st, x_prev=tx)
        h = h + y
        y, cx = R.channelmix_apply(cfg, bp["cm"],
                                   L.norm_apply(cfg, bp["ln2"], h), x_prev=cx)
        return h + y, (st, tx, cx)

    x, (st, tx, cx) = jax.lax.scan(
        body, x, (params["blocks"], cache["tm_state"], cache["tm_x"],
                  cache["cm_x"]))
    x = L.norm_apply(cfg, params["ln_f"], x[:, -1:])
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {"tm_state": st, "tm_x": tx, "cm_x": cx}


# ===========================================================================
# encoder-decoder (whisper): stub conv frontend -> enc_embeds in the batch


def _encdec_init(cfg, key):
    ke, kenc, kdec, kn = jax.random.split(key, 4)

    def enc_block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"ln_attn": L.norm_init(cfg, k1), "attn": L.attn_init(cfg, k2),
                "ln_ffn": L.norm_init(cfg, k3), "mlp": L.mlp_init(cfg, k4)}

    def dec_block(k):
        k1, k2, k3, k4, k5, k6 = jax.random.split(k, 6)
        return {"ln_self": L.norm_init(cfg, k1), "self": L.attn_init(cfg, k2),
                "ln_cross": L.norm_init(cfg, k3), "cross": L.attn_init(cfg, k4),
                "ln_ffn": L.norm_init(cfg, k5), "mlp": L.mlp_init(cfg, k6)}

    return {
        "embed": L.embed_init(cfg, ke),
        "enc_blocks": jax.vmap(enc_block)(jax.random.split(kenc, cfg.enc_layers)),
        "dec_blocks": jax.vmap(dec_block)(jax.random.split(kdec, cfg.dec_layers)),
        "ln_enc": L.norm_init(cfg, kn),
        "ln_f": L.norm_init(cfg, jax.random.fold_in(kn, 1)),
    }


def _encode(cfg, params, enc_embeds):
    x = enc_embeds.astype(L._dt(cfg))
    positions = jnp.arange(x.shape[1])[None]

    def body(h, bp):
        h = h + L.attn_apply(cfg, bp["attn"],
                             L.norm_apply(cfg, bp["ln_attn"], h),
                             positions=positions, causal=False)
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln_ffn"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.norm_apply(cfg, params["ln_enc"], x)


def _cross_kv(cfg, p, enc_out):
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = L.dense(p["wk"], enc_out).reshape(b, t, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], enc_out).reshape(b, t, cfg.n_kv_heads, hd)
    return k, v


def _encdec_forward(cfg, params, batch, last_only=False):
    enc_out = _encode(cfg, params, batch["enc_embeds"])
    x = L.embed_apply(cfg, params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1])[None]

    def body(h, bp):
        h = h + L.attn_apply(cfg, bp["self"],
                             L.norm_apply(cfg, bp["ln_self"], h),
                             positions=positions, causal=True)
        kv = _cross_kv(cfg, bp["cross"], enc_out)
        h = h + L.cross_attn_apply(cfg, bp["cross"],
                                   L.norm_apply(cfg, bp["ln_cross"], h), kv)
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln_ffn"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.norm_apply(cfg, params["ln_f"], x)
    if last_only:
        x = x[:, -1:]
    return x @ params["embed"]["tok"].T, jnp.float32(0.0)   # whisper ties


def _encdec_loss(cfg, params, batch, perturb=None):
    if perturb is not None:           # transient copy; see _hybrid_loss
        params = perturb.materialize(params)
    logits, _ = _encdec_forward(cfg, params, batch)
    return softmax_xent(logits, batch["targets"], batch.get("loss_mask"))


def _encdec_init_cache(cfg, bsz, max_len, dtype):
    hd = cfg.resolved_head_dim
    ll = cfg.dec_layers
    return {
        "k": jnp.zeros((ll, bsz, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((ll, bsz, max_len, cfg.n_kv_heads, hd), dtype),
        # cross-attention K/V precomputed from the encoder once per request
        "xk": jnp.zeros((ll, bsz, cfg.enc_len, cfg.n_kv_heads, hd), dtype),
        "xv": jnp.zeros((ll, bsz, cfg.enc_len, cfg.n_kv_heads, hd), dtype),
    }


def _encdec_decode_step(cfg, params, cache, tokens, pos):
    x = L.embed_apply(cfg, params["embed"], tokens,
                      positions=_decode_positions(pos))

    def body(h, xs):
        bp, ck, cv, xk, xv = xs
        a, ck, cv = _decode_attn(cfg, bp["self"],
                                 L.norm_apply(cfg, bp["ln_self"], h), ck, cv,
                                 pos)
        h = h + a
        h = h + L.cross_attn_apply(cfg, bp["cross"],
                                   L.norm_apply(cfg, bp["ln_cross"], h),
                                   (xk, xv))
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln_ffn"], h))
        return h, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = x @ params["embed"]["tok"].T
    return logits, {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}


# ===========================================================================
# registry


def build_model(cfg: ModelConfig) -> Model:
    dtype = L._dt(cfg)
    if cfg.family in ("dense", "moe"):
        fwd = _cls_forward if cfg.n_classes else _lm_forward
        return Model(
            cfg=cfg,
            init=partial(_lm_init, cfg),
            forward=partial(fwd, cfg),
            loss=partial(_lm_loss, cfg),
            init_cache=lambda bsz, max_len=None: _lm_init_cache(
                cfg, bsz, max_len or cfg.max_seq, dtype),
            decode_step=partial(_lm_decode_step, cfg),
            prefill=None if cfg.n_classes else partial(_lm_prefill, cfg),
        )
    if cfg.family == "encoder":
        return Model(
            cfg=cfg, init=partial(_lm_init, cfg),
            forward=partial(_cls_forward, cfg),
            loss=partial(_lm_loss, cfg),
            init_cache=lambda *a, **k: (_ for _ in ()).throw(
                ValueError("encoder-only arch has no decode step")),
            decode_step=None,
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg, init=partial(_hybrid_init, cfg),
            forward=partial(_hybrid_forward, cfg),
            loss=partial(_hybrid_loss, cfg),
            init_cache=lambda bsz, max_len=None: _hybrid_init_cache(
                cfg, bsz, max_len or cfg.max_seq, dtype),
            decode_step=partial(_hybrid_decode_step, cfg),
            prefill=partial(_hybrid_prefill, cfg),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg, init=partial(_rwkv_init, cfg),
            forward=partial(_rwkv_forward, cfg),
            loss=partial(_rwkv_loss, cfg),
            init_cache=lambda bsz, max_len=None: _rwkv_init_cache(
                cfg, bsz, max_len or cfg.max_seq, dtype),
            decode_step=partial(_rwkv_decode_step, cfg),
            prefill=partial(_rwkv_prefill, cfg),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg, init=partial(_encdec_init, cfg),
            forward=partial(_encdec_forward, cfg),
            loss=partial(_encdec_loss, cfg),
            init_cache=lambda bsz, max_len=None: _encdec_init_cache(
                cfg, bsz, max_len or cfg.max_seq, dtype),
            decode_step=partial(_encdec_decode_step, cfg),
        )
    raise ValueError(f"unknown family {cfg.family}")
