"""Checkpoint store, replay log, and crash-recovery semantics."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, ReplayLog, latest_step,
                              load_params, save_params)
from repro.checkpoint.replay_log import replay_into
from repro.core import MezoConfig, mezo_step_vmapdir


def _params(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": {"w": jax.random.normal(k, (8, 16))},
            "b": jnp.arange(5, dtype=jnp.float32)}


def test_save_load_roundtrip(tmp_path):
    p = _params()
    save_params(str(tmp_path), 3, p)
    assert latest_step(str(tmp_path)) == 3
    q = load_params(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, p))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_overwrite(tmp_path):
    p = _params()
    save_params(str(tmp_path), 1, p)
    p2 = jax.tree.map(lambda x: x + 1, p)
    save_params(str(tmp_path), 1, p2)
    q = load_params(str(tmp_path), 1, p)
    np.testing.assert_array_equal(np.asarray(q["b"]),
                                  np.asarray(p["b"] + 1))


def test_replay_log_roundtrip(tmp_path):
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    log.append(0, 123, [0.5, -0.25], 1e-3, 1e-2)
    log.append(1, 456, [0.1, 0.2], 1e-3, 1e-2)
    log.close()
    recs = ReplayLog.read(path)
    assert [r["step"] for r in recs] == [0, 1]
    assert recs[0]["gs"] == [0.5, -0.25]


def test_replay_log_torn_tail(tmp_path):
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    log.append(0, 1, [0.5], 1e-3, 1e-2)
    log.close()
    with open(path, "a") as f:
        f.write('{"step": 1, "seed": 2, "gs"')  # torn write
    with pytest.warns(RuntimeWarning, match="dropped 1 corrupt"):
        recs = ReplayLog.read(path)
    assert len(recs) == 1 and recs[0]["step"] == 0


def test_replay_log_torn_middle_recovers_tail(tmp_path):
    """A crash mid-append followed by a restart leaves a corrupt line in
    the MIDDLE of the log (the restart retries the step and keeps
    appending). read() must warn with the drop count and keep everything
    valid -- including records after the tear -- with the retried step
    deduplicated."""
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    log.append(0, 1, [0.5], 1e-3, 1e-2)
    log.close()
    with open(path, "a") as f:
        f.write('{"step": 1, "seed": 2, "gs"')        # torn write (crash),
    log = ReplayLog(path)          # NO trailing newline; restart must seal
    log.append(1, 2, [0.25], 1e-3, 1e-2)              # retried step
    log.append(1, 2, [0.25], 1e-3, 1e-2)              # duplicate retry
    log.append(2, 3, [0.125], 1e-3, 1e-2)
    log.close()
    with pytest.warns(RuntimeWarning, match="dropped 1 corrupt"):
        recs = ReplayLog.read(path)
    assert [r["step"] for r in recs] == [0, 1, 2]
    assert recs[1]["gs"] == [0.25]


def test_replay_log_dedup(tmp_path):
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    log.append(0, 1, [0.5], 1e-3, 1e-2)
    log.append(0, 1, [0.5], 1e-3, 1e-2)  # retried step
    log.close()
    assert len(ReplayLog.read(path)) == 1


def test_replay_into_matches_live_update(tmp_path):
    params = _params(1)

    def loss_fn(p, _):
        return jnp.sum(p["a"]["w"] ** 2) * 1e-3 + jnp.sum(p["b"] ** 2) * 1e-3

    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    p_live = jax.tree.map(jnp.copy, params)
    recs = []
    for t in range(5):
        p_live, aux = mezo_step_vmapdir(loss_fn, p_live, None,
                                        jnp.uint32(t), cfg)
        recs.append({"step": t, "seed": int(aux.seed),
                     "gs": np.asarray(aux.gs).tolist(),
                     "lr": cfg.lr, "eps": cfg.eps})
    p_replay, last = replay_into(jax.tree.map(jnp.copy, params), recs, cfg)
    assert last == 4
    for a, b in zip(jax.tree.leaves(p_live), jax.tree.leaves(p_replay)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_manager_restore_snapshot_plus_log(tmp_path):
    params = _params(2)

    def loss_fn(p, _):
        return jnp.sum(p["a"]["w"] ** 2) * 1e-3

    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=1)
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=3)
    p = jax.tree.map(jnp.copy, params)
    for t in range(7):
        p, aux = mezo_step_vmapdir(loss_fn, p, None, jnp.uint32(t), cfg)
        mgr.on_step(t, p, aux)
    # snapshot at 6 + log 0..6 -> restore resumes at 7
    restored, nxt = CheckpointManager(
        str(tmp_path), mezo_cfg=cfg, snapshot_every=3).restore(params)
    assert nxt == 7
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
