"""Decoder cross-attention block (encoder-decoder / whisper).

Full-sequence apply projects K/V from ``rc.enc_out`` on the fly (ZO
perturbation included via ctx); decode/prefill instead read ``(xk, xv)``
from the block's state and *never write* it (``mutable_state=False``
keeps the runtime from copying it through the layer scan every token).
A caller with encoder output populates the state via ``cross_kv`` per
layer; the serving engine currently admits token-only requests, so its
cross state stays at ``init_cache``'s zeros (decode then conditions on
tokens alone -- same as the per-token reference loop)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.models import layers as L
from repro.models.blocks.base import BlockType, register_block


def cross_kv(cfg, p, enc_out, ctx=None):
    """Project encoder output to this layer's cross K/V."""
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = L.dense(p["wk"], enc_out, _sub(ctx, "wk")).reshape(
        b, t, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], enc_out, _sub(ctx, "wv")).reshape(
        b, t, cfg.n_kv_heads, hd)
    return k, v


def _apply(cfg, p, x, rc, ctx=None):
    kv = cross_kv(cfg, p, rc.enc_out, ctx)
    return L.cross_attn_apply(cfg, p, x, kv, ctx=ctx), jnp.float32(0.0)


def _state_spec(cfg, bsz, max_len, dtype):
    shape = (bsz, cfg.enc_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"xk": (shape, dtype), "xv": (shape, dtype)}


def _from_state(cfg, p, state, x, rc, ctx=None):
    y = L.cross_attn_apply(cfg, p, x, (state["xk"], state["xv"]))
    return y, state


CROSS_ATTENTION = register_block(BlockType(
    name="cross_attention", init=L.attn_init, apply=_apply,
    state_spec=_state_spec, prefill=_from_state, decode_step=_from_state,
    mutable_state=False))
