"""Flash-decoding over a paged KV cache (Pallas TPU + jnp reference).

Decode is single-token attention: one query row per sequence against
everything that sequence has cached. The dense path reads the full
(B, S_max) cache every step -- including the dead tail beyond each
slot's position -- and its HBM traffic is what caps decode tok/s
(table3). This kernel reads K/V as fixed-size *pages* gathered through a
per-slot page table, splits the key axis across a grid dimension, and
reduces with online-softmax partials (acc, m, l) in VMEM scratch:

  * pages whose first position lies beyond the slot's ``pos`` are dead
    for the whole tile -- the ``pl.when`` guard skips their dot entirely
    (flash-decoding's "only read what is resident"),
  * the page gather is a BlockSpec index map over a scalar-prefetched
    page table (``pltpu.PrefetchScalarGridSpec``): the DMA engine fetches
    pool page ``pages[b, p]`` directly, no materialized (B, S, ...)
    contiguous copy of the cache ever exists.

Layout: q (B, H, hd) -- one token per slot; k/v pools
(n_pages, page_size, KV, hd); pages (B, n_live) physical page ids;
pos (B,) each slot's current position. Grid (B, KV, n_live), pages
innermost. GQA: the G = H//KV query heads of one KV head share a tile.

``paged_attn_ref`` is the pure-jnp oracle (gather + masked softmax) --
also the hot-path implementation on non-TPU backends, where interpret
mode would run the kernel body in Python per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30

# full-head-dim tiles: the lane axis must fill (or evenly split) the
# 128-wide MXU; the sets below are what the q/k dot supports without
# implicit padding that silently corrupts the accumulation
MXU_HEAD_DIMS = (64, 112, 128, 256)


def check_head_dim(hd: int, *, interpret: bool, kernel: str):
    """Registry-style validation: on TPU an unsupported head dim must be
    a loud error, not silent tile-padding misbehavior. Interpret mode
    (CI parity tests) runs any head dim."""
    if not interpret and hd not in MXU_HEAD_DIMS:
        raise ValueError(
            f"{kernel}: head_dim {hd} is not MXU-aligned; supported head "
            f"dims: {list(MXU_HEAD_DIMS)} (interpret=True lifts this for "
            f"correctness tests)")


def _decode_kernel(pages_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, ps, n_live, scale):
    bi = pl.program_id(0)
    pp = pl.program_id(2)

    @pl.when(pp == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[bi]
    # a page is live iff its first slot is <= pos; later pages of the
    # table hold this slot's future (or another slot's trash) -- skipped
    live = pp * ps <= pos

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (ps, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        k_pos = pp * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(k_pos <= pos, s, _NEG_INF)             # (G, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(pp == n_live - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q, k_pages, v_pages, pages, pos, *,
                 interpret: bool = False):
    """q: (B, H, hd); k/v pools: (NP, ps, KV, hd); pages: (B, n_live)
    int32 physical page ids; pos: (B,) int32 -> (B, H, hd).

    Positions > pos[b] (this slot's dead tail, unallocated table entries
    pointing at the trash page) are masked out; page n_live*ps .. S_max
    is never read at all.
    """
    b, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    g = h // kvh
    n_live = pages.shape[1]
    check_head_dim(hd, interpret=interpret, kernel="flash_decode")
    qg = q.reshape(b, kvh, g, hd)

    def qmap(bi, kv, pp, pages_ref, pos_ref):
        return (bi, kv, 0, 0)

    def kvmap(bi, kv, pp, pages_ref, pos_ref):
        return (pages_ref[bi, pp], 0, kv, 0)

    kern = functools.partial(_decode_kernel, ps=ps, n_live=n_live,
                             scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # pages, pos
        grid=(b, kvh, n_live),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), qmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), pos.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, h, hd)


def paged_attn_ref(q, k_pages, v_pages, pages, pos):
    """jnp oracle / non-TPU hot path: gather the live pages back into
    logical order and run masked GQA attention over them. Reads
    n_live * ps keys instead of S_max -- the same dead-tail skip the
    kernel does, expressed as a (bucketed-static) gather."""
    b, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    n_live = pages.shape[1]
    kk = k_pages[pages].reshape(b, n_live * ps, kvh, hd)
    vv = v_pages[pages].reshape(b, n_live * ps, kvh, hd)
    valid = jnp.arange(n_live * ps)[None, :] <= pos[:, None]
    from repro.models.layers import attention
    out = attention(q[:, None], kk, vv, causal=False, kv_mask=valid,
                    chunk=0)
    return out[:, 0]
