"""Trainer integration: fault injection + resume, straggler + adam arms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batches
from repro.optim.adam import AdamConfig
from repro.runtime import StragglerPolicy, Trainer, TrainerConfig

CFG = get_config("qwen3-4b").reduced()


def _batches(start=0):
    return lm_batches(4, 16, CFG.vocab, seed=3, start_step=start)


def test_crash_resume_matches_uninterrupted(tmp_path):
    n = 14
    mz = MezoConfig(eps=1e-2, lr=1e-2, n_directions=2)

    tc_a = TrainerConfig(optimizer="mezo", mezo=mz, n_steps=n,
                         ckpt_dir=str(tmp_path / "a"), snapshot_every=5,
                         log_every=100)
    tr_a = Trainer(CFG, tc_a, _batches())
    p_full = tr_a.train()

    tc_b = TrainerConfig(optimizer="mezo", mezo=mz, n_steps=n,
                         ckpt_dir=str(tmp_path / "b"), snapshot_every=5,
                         log_every=100)
    with pytest.raises(RuntimeError):
        Trainer(CFG, tc_b, _batches()).train(fail_at=9)
    # fresh process resumes from snapshot@5 + replay 6..8
    tr_c = Trainer(CFG, tc_b, _batches(start=9))
    p_res = tr_c.train()

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=5e-5)


def test_adam_arm_descends():
    tc = TrainerConfig(optimizer="adam", adam=AdamConfig(lr=3e-3),
                       n_steps=15, log_every=100)
    tr = Trainer(CFG, tc, _batches())
    tr.train()
    assert tr.losses[-1] < tr.losses[0]


def test_straggler_policy_masks():
    pol = StragglerPolicy(n_directions=4, redundancy=2)
    m = pol.mask()
    assert m.shape == (6,)
    assert m.sum() == 6  # no latency info yet -> keep all
    pol.observe([1, 1, 1, 1, 1, 50.0])
    m = pol.mask()
    assert m[5] == 0          # slow direction dropped
    assert m.sum() <= 4       # fastest-K selection
    m2 = pol.mask(slow=[0])
    assert m2[0] == 0


def test_straggler_trainer_arm():
    tc = TrainerConfig(optimizer="mezo-parallel",
                       mezo=MezoConfig(eps=1e-2, lr=1e-2, n_directions=2),
                       n_steps=3, straggler_redundancy=2, log_every=100)
    tr = Trainer(CFG, tc, _batches())
    tr.train()
    assert len(tr.losses) == 3


def test_unknown_quant_mode_raises_with_supported_list():
    """Mirrors the estimator/update registry errors: an unknown --quant
    value must raise a ValueError naming the supported modes."""
    with pytest.raises(ValueError, match=r"int4.*none.*int8"):
        Trainer(CFG, TrainerConfig(quant="int4"), _batches())


def test_quant_rejects_gradient_baseline():
    with pytest.raises(ValueError, match="frozen"):
        Trainer(CFG, TrainerConfig(optimizer="adam", quant="int8"),
                _batches())


def test_quantized_trainer_arm_runs_and_freezes_base():
    """--quant int8 end to end on the fused strategy: losses flow, the
    int8 values stay bit-frozen, the update stream lands in the deltas."""
    from repro.optim.quant import is_quantized, quantize_tree

    tc = TrainerConfig(optimizer="mezo-fused", quant="int8",
                       mezo=MezoConfig(eps=1e-2, lr=1e-2, n_directions=2),
                       n_steps=3, log_every=100)
    tr = Trainer(CFG, tc, _batches())
    trained = tr.train()
    assert len(tr.losses) == 3
    q0 = quantize_tree(tr.model.init(jax.random.PRNGKey(tc.seed)))
    moved = 0.0
    for a, b in zip(jax.tree.leaves(trained, is_leaf=is_quantized),
                    jax.tree.leaves(q0, is_leaf=is_quantized)):
        if is_quantized(a):
            np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
            np.testing.assert_array_equal(np.asarray(a.scale),
                                          np.asarray(b.scale))
            moved += float(np.abs(np.asarray(a.delta)).sum())
    assert moved > 0.0
