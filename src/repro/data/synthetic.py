"""Deterministic synthetic datasets with planted, learnable structure.

No internet in this environment, so the paper's datasets (SST-2,
SuperGLUE) are stood in for by synthetic corpora whose losses *can*
descend, which is what the paper's Figure 1 demonstrates:

* ``synthetic_lm_corpus`` -- a first-order Markov language over ``vocab``
  tokens (each token strongly predicts a successor), so next-token CE has
  ~2 nats of learnable signal below the uniform-prior loss.

* ``synthetic_sst2`` -- the paper's RoBERTa/SST-2 task shape: binary
  "sentiment" where a handful of planted lexicon tokens determine the
  label.
"""

from __future__ import annotations

import numpy as np


def synthetic_lm_corpus(n_tokens: int, vocab: int, seed: int = 0,
                        peakiness: float = 0.85) -> np.ndarray:
    """Markov-chain token stream: P(next = succ(tok)) = peakiness."""
    rng = np.random.default_rng(seed)
    succ = rng.permutation(vocab)
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(vocab)
    jump = rng.random(n_tokens) > peakiness
    rand = rng.integers(0, vocab, n_tokens)
    for i in range(1, n_tokens):
        toks[i] = rand[i] if jump[i] else succ[toks[i - 1]]
    return toks


def lm_batch_at(step: int, batch: int, seq: int, vocab: int,
                stream: np.ndarray, seed: int = 0):
    """Batch addressed by step index -- resume at step N replays exactly
    the batch an uninterrupted run would have seen (checkpoint/restart
    determinism)."""
    rng = np.random.default_rng((seed + 1) * 1_000_003 + step)
    n = len(stream) - 1
    starts = rng.integers(0, n - seq - 1, batch)
    idx = starts[:, None] + np.arange(seq + 1)[None]
    chunk = stream[idx]
    return {
        "tokens": chunk[:, :-1].astype(np.int32),
        "targets": chunk[:, 1:].astype(np.int32),
        "loss_mask": np.ones((batch, seq), np.float32),
    }


def lm_batches(batch: int, seq: int, vocab: int, seed: int = 0,
               n_steps: int = 10 ** 9, start_step: int = 0):
    """Yields step-indexed {tokens, targets, loss_mask} dicts."""
    stream = synthetic_lm_corpus((batch * (seq + 1)) * 64, vocab, seed)
    for step in range(start_step, n_steps):
        yield lm_batch_at(step, batch, seq, vocab, stream, seed)


def synthetic_sst2(n: int, seq: int, vocab: int, seed: int = 0):
    """Planted-lexicon binary classification (SST-2 stand-in)."""
    rng = np.random.default_rng(seed)
    n_lex = max(8, vocab // 16)
    pos_lex = rng.choice(vocab - 1, n_lex, replace=False) + 1
    neg_lex = rng.choice(vocab - 1, n_lex, replace=False) + 1
    toks = rng.integers(1, vocab, (n, seq)).astype(np.int32)
    labels = rng.integers(0, 2, n).astype(np.int32)
    # plant 3 lexicon tokens per example at random positions (not pos 0)
    for i in range(n):
        lex = pos_lex if labels[i] else neg_lex
        pos = rng.choice(seq - 1, 3, replace=False) + 1
        toks[i, pos] = rng.choice(lex, 3)
    toks[:, 0] = 0  # CLS
    return toks, labels


def sst2_batches(batch: int, seq: int, vocab: int, seed: int = 0,
                 n_examples: int = 4096):
    toks, labels = synthetic_sst2(n_examples, seq, vocab, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        idx = rng.integers(0, n_examples, batch)
        yield {"tokens": toks[idx], "label": labels[idx]}
