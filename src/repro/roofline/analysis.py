"""Three-term roofline from a compiled dry-run artifact.

  compute     = HLO_FLOPs   / peak_FLOP/s          (per chip)
  memory      = HLO_bytes   / HBM_bw               (per chip)
  collective  = coll_bytes  / ICI_bw               (per chip, parsed HLO)

``compiled.cost_analysis()`` on an SPMD executable reports per-device
flops/bytes. MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE) is computed
analytically from the config for the usefulness ratio.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo import collective_bytes


def active_params(cfg) -> float:
    """Parameters touched per token (active experts only for MoE)."""
    d, v = cfg.d_model, cfg.vocab
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    emb = v * d  # embedding lookup is sparse; count once for lm_head

    def attn_p():
        return d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d

    def mlp_p(f):
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        return mult * d * f

    if cfg.family == "ssm":
        per_layer = 4 * d * d + d * d + 3 * d * cfg.d_ff  # rwkv tm + cm
        return cfg.n_layers * per_layer + emb
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.block_len
        di = cfg.mamba_expand * d
        mamba_p = 2 * d * di + di * d  # in/out proj dominate
        per_block = (cfg.block_len - 1) * mamba_p + attn_p()
        # ffn: half dense, half moe(topk active)
        n_moe = cfg.block_len // 2
        n_dense = cfg.block_len - n_moe
        f = cfg.expert_dff or cfg.d_ff
        per_block += n_dense * mlp_p(cfg.d_ff) + n_moe * cfg.topk * mlp_p(f)
        return nb * per_block + emb
    if cfg.family == "encdec":
        per = attn_p() + mlp_p(cfg.d_ff)
        return (cfg.enc_layers * per + cfg.dec_layers * (per + attn_p())
                + emb)
    per_layer = attn_p()
    if cfg.n_experts:
        per_layer += cfg.topk * mlp_p(cfg.expert_dff or cfg.d_ff)
        per_layer += cfg.n_shared_experts * mlp_p(cfg.expert_dff or cfg.d_ff)
    else:
        per_layer += mlp_p(cfg.d_ff)
    return cfg.n_layers * per_layer + emb


def total_params(cfg) -> float:
    if not cfg.n_experts:
        return active_params(cfg)
    d = cfg.d_model
    f = cfg.expert_dff or cfg.d_ff
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_expert = mult * d * f
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.block_len
        n_moe_layers = nb * (cfg.block_len // 2)
    else:
        n_moe_layers = cfg.n_layers
    extra = n_moe_layers * (cfg.n_experts - cfg.topk) * per_expert
    return active_params(cfg) + extra


def model_flops(cfg, n_tokens: int, mode: str) -> float:
    """6*N_active*D for train (fwd+bwd); ZO train = 2 forwards = 4*N*D;
    prefill/decode = 2*N*D per token."""
    n = active_params(cfg)
    per_tok = {"train": 4.0, "train-adam": 6.0, "prefill": 2.0,
               "decode": 2.0}[mode]
    return per_tok * n * n_tokens


def roofline_terms(cost: Dict, hlo_text: Optional[str], n_chips: int,
                   cfg=None, n_tokens: int = 0, mode: str = "train",
                   flops_override: Optional[float] = None) -> Dict:
    """All terms in seconds-per-step (per chip).

    Primary source is the loop-aware HLO analyzer (xla's cost_analysis
    counts scan bodies once -- see roofline/hlo.py); raw cost_analysis
    values are kept alongside for reference.
    """
    la = None
    if hlo_text:
        from repro.roofline.hlo import analyze
        la = analyze(hlo_text)
    if flops_override is not None:
        flops = flops_override
    elif la is not None:
        flops = la["flops"]
    else:
        flops = float(cost.get("flops", 0.0))
    bytes_hbm = (la["hbm_bytes"] if la is not None
                 else float(cost.get("bytes accessed", 0.0)))
    coll = la["collective_bytes"] if la is not None else 0.0

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll / ICI_BW
    terms = {
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": bytes_hbm,
        "collective_bytes_per_chip": coll,
        "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "raw_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
    }
    if cfg is not None and n_tokens:
        mf = model_flops(cfg, n_tokens, mode)
        terms["model_flops_total"] = mf
        hw_total = flops * n_chips
        terms["useful_flops_ratio"] = (mf / hw_total) if hw_total else 0.0
        # roofline fraction: useful model flops per chip over the step's
        # bound (the dominant term) at peak
        t_bound = max(t_compute, t_memory, t_coll)
        if t_bound > 0:
            terms["roofline_fraction"] = (
                (mf / n_chips) / PEAK_FLOPS_BF16) / t_bound
    return terms
