"""Shared neural layers: norms, RoPE, attention (GQA/MQA, chunked), MLPs.

Conventions:
  * params are plain nested dicts of jnp arrays,
  * activations flow in the param dtype (bf16 on TPU), softmax/norm math
    in f32,
  * every parameterized apply-fn takes an optional ``ctx``
    (:class:`repro.core.perturb_ctx.PerturbCtx`, scoped to its param
    sub-dict). ``ctx=None`` is the plain forward; with a ctx, dense
    weights compute X @ (W + coeff*z) through the fused ZO kernel and all
    other leaves add a transient coeff*z -- the perturbed forward of the
    fused MeZO step, bit-compatible with perturbing the param tree,
  * attention is memory-efficient: for long sequences the query axis is
    processed in chunks under ``lax.scan`` so the (S, T) score tensor is
    never materialized in full (prefill_32k / train_4k would otherwise
    need hundreds of GB of scores per device).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.optim.quant import deq as _deq
from repro.optim.quant import take_rows as _take_rows

# ---------------------------------------------------------------------------
# norms


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_init(cfg, key):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def norm_apply(cfg, p, x, ctx=None):
    if ctx is not None:
        p = {k: ctx.perturb(k, v) for k, v in p.items()}
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary position embedding (full / partial per rope_pct)


def rope_cos_sin(positions, head_dim: int, rope_pct: float, theta: float):
    """positions: int array (...,). Returns cos/sin of shape (..., rot/2)."""
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    if rot == 0:
        return None
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos_sin):
    """x: (..., S, H, hd); cos/sin: (..., S, rot/2) broadcast over H."""
    if cos_sin is None:
        return x
    cos, sin = cos_sin
    rot2 = cos.shape[-1]
    xr, xp = x[..., :2 * rot2], x[..., 2 * rot2:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# dense projections


def dense_init(key, d_in, d_out, dtype, scale=0.02, bias=False):
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, ctx=None):
    """ctx=None is the plain forward (quantized weights dequantize
    transiently at the use site); with a ctx the perturbation -- and for
    a quantized base the dequant too -- fuses into the matmul."""
    y = x @ _deq(p["w"]) if ctx is None else ctx.matmul(x, p["w"], "w")
    if "b" in p:
        y = y + (_deq(p["b"]) if ctx is None else ctx.perturb("b", p["b"]))
    return y


# ---------------------------------------------------------------------------
# attention


def _sdpa(q, k, v, mask, dtype):
    """q: (B, S, KV, G, hd); k/v: (B, T, KV, hd); mask broadcastable to
    (B, KV, G, S, T). Softmax in f32."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(dtype), v)
    return out


def attention(q, k, v, *, causal: bool, q_offset=0,
              kv_mask: Optional[jnp.ndarray] = None, chunk: int = 0):
    """GQA attention. q: (B, S, H, hd); k/v: (B, T, KV, hd).

    kv_mask is (B, T) key validity shared by every query row, or
    (B, S, T) with a mask per query row (speculative verify windows:
    each candidate token has its own position limit).

    chunk > 0 and S % chunk == 0 and S > chunk: scan over query chunks so
    peak score memory is (B, H, chunk, T) instead of (B, H, S, T).
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    kv_pos = jnp.arange(t)

    def block_mask(q_pos):
        m = jnp.ones((q_pos.shape[0], t), bool)
        if causal:
            m = q_pos[:, None] >= kv_pos[None, :]
        m = m[None, None, None]                      # (1,1,1,S,T)
        if kv_mask is not None:
            if kv_mask.ndim == 3:                    # per-query-row masks
                rows = jnp.take(kv_mask, q_pos - q_offset, axis=1)
                m = m & rows[:, None, None, :, :]    # (B,1,1,S,T)
            else:
                m = m & kv_mask[:, None, None, None, :]  # (B,1,1,1,T)
        return m

    if chunk and s > chunk and s % chunk == 0:
        nc = s // chunk
        qc = qg.reshape(b, nc, chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)

        def body(_, inp):
            qi, ci = inp
            q_pos = q_offset + ci * chunk + jnp.arange(chunk)
            return None, _sdpa(qi, k, v, block_mask(q_pos), q.dtype)

        _, out = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)
        return out

    q_pos = q_offset + jnp.arange(s)
    out = _sdpa(qg, k, v, block_mask(q_pos), q.dtype)
    return out.reshape(b, s, h, hd)


def attn_init(cfg, key, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    bias = cfg.norm == "layernorm"
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, _dt(cfg), bias=bias),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, _dt(cfg), bias=bias),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, _dt(cfg), bias=bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, _dt(cfg),
                         scale=0.02 / max(cfg.n_layers, 1) ** 0.5, bias=bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attn_project_qkv(cfg, p, x, ctx=None):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x, _sub(ctx, "wq")).reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], x, _sub(ctx, "wk")).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x, _sub(ctx, "wv")).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        qn = p["q_norm"] if ctx is None else ctx.perturb("q_norm", p["q_norm"])
        kn = p["k_norm"] if ctx is None else ctx.perturb("k_norm", p["k_norm"])
        q = rmsnorm(q, qn)
        k = rmsnorm(k, kn)
    return q, k, v


def attn_apply(cfg, p, x, *, positions=None, kv_mask=None, causal=None,
               ctx=None):
    """Self-attention over x: (B, S, D). positions: (B, S) or None."""
    b, s, _ = x.shape
    q, k, v = attn_project_qkv(cfg, p, x, ctx)
    if cfg.pos == "rope":
        pos = positions if positions is not None else jnp.arange(s)[None]
        cs = rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_pct,
                          cfg.rope_theta)
        q, k = apply_rope(q, cs), apply_rope(k, cs)
    causal = cfg.causal if causal is None else causal
    if cfg.attn_impl == "flash" and kv_mask is None:
        from repro.kernels.flash_attention import flash_attention
        import jax as _jax
        out = flash_attention(q, k, v, causal=causal,
                              interpret=_jax.default_backend() != "tpu")
    else:
        out = attention(q, k, v, causal=causal, kv_mask=kv_mask,
                        chunk=cfg.attn_chunk)
    return dense(p["wo"], out.reshape(b, s, -1), _sub(ctx, "wo"))


def cross_attn_apply(cfg, p, x, enc_kv, ctx=None):
    """Decoder cross-attention (whisper): kv from encoder output (the
    K/V projections perturb where kv is computed -- blocks/cross_attention)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x, _sub(ctx, "wq")).reshape(b, s, cfg.n_heads, hd)
    k, v = enc_kv
    out = attention(q, k, v, causal=False, chunk=0)
    return dense(p["wo"], out.reshape(b, s, -1), _sub(ctx, "wo"))


# ---------------------------------------------------------------------------
# MLPs


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def mlp_init(cfg, key, d_ff=None, d_model=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    bias = cfg.norm == "layernorm"
    k1, k2 = jax.random.split(key)
    gated = cfg.act in ("swiglu", "geglu")
    if gated:
        # interleaved (D, F, 2) layout: up/gate pairs live on the SAME
        # tensor-parallel shard, so the split below is shard-local. The
        # flat (D, 2F) layout splits across the model axis and costs a
        # collective-permute of the whole hidden per layer (measured:
        # 57 GB/chip/step on qwen3-4b train_4k -- EXPERIMENTS.md Sec Perf)
        w = (jax.random.normal(k1, (d, f, 2), jnp.float32) * 0.02
             ).astype(_dt(cfg))
        p_in = {"w": w}
    else:
        p_in = dense_init(k1, d, f, _dt(cfg), bias=bias)
    return {
        "w_in": p_in,
        "w_out": dense_init(k2, f, d, _dt(cfg),
                            scale=0.02 / max(cfg.n_layers, 1) ** 0.5,
                            bias=bias),
    }


def mlp_apply(cfg, p, x, ctx=None):
    if cfg.act in ("swiglu", "geglu"):
        # gated w_in is an interleaved (D, F, 2) leaf: its z-field spans 3
        # dims, so the 2-D fused kernel doesn't apply -- transient perturb
        w_in = _deq(p["w_in"]["w"]) if ctx is None else \
            ctx.perturb("w_in/w", p["w_in"]["w"])
        h = jnp.einsum("...d,dfg->...fg", x, w_in)
        u, g = h[..., 0], h[..., 1]
        gate = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = u * gate
    else:
        h = dense(p["w_in"], x, _sub(ctx, "w_in"))
        h = jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.relu(h)
    return dense(p["w_out"], h, _sub(ctx, "w_out"))


# ---------------------------------------------------------------------------
# embedding


def embed_init(cfg, key):
    e = {"tok": (jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(_dt(cfg))}
    if cfg.pos == "learned":
        e["pos"] = (jax.random.normal(jax.random.fold_in(key, 1),
                                      (cfg.max_seq, cfg.d_model), jnp.float32)
                    * 0.02).astype(_dt(cfg))
    return e


def embed_apply(cfg, p, tokens, positions=None, ctx=None):
    """ctx (scoped to "embed") perturbs only the gathered rows: O(S*D)
    transient z, never the (V, D) table."""
    if ctx is None:
        x = _take_rows(p["tok"], tokens)
    else:
        x = ctx.take("tok", p["tok"], tokens)
    if cfg.pos == "learned":
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        if ctx is None:
            x = x + _take_rows(p["pos"], pos)
        else:
            x = x + ctx.take("pos", p["pos"], pos)
    return x


def unembed(cfg, embed_p, head_p, x, ctx=None):
    """Final projection to vocab logits (tied or untied). ctx is scoped to
    the param-tree ROOT here (the two branches touch different leaves)."""
    if cfg.tie_embeddings or head_p is None:
        if ctx is None:
            return x @ _deq(embed_p["tok"]).T
        # tied head reads the embedding transposed; the row-major z-field
        # doesn't transpose into kernel tiles, so perturb transiently
        return x @ ctx.scope("embed").perturb("tok", embed_p["tok"]).T
    return dense(head_p, x, _sub(ctx, "lm_head"))
