"""MeZO core: descent, estimator quality, replay, direction masks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MezoConfig, add_scaled_z, mezo_step,
                        mezo_step_vmapdir, replay_update,
                        spsa_gradient_estimate)


@pytest.fixture
def quad():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 8)), "b": jnp.zeros((8,))}
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = x @ (jnp.eye(8) * 0.1)

    def loss_fn(p, batch):
        xx, yy = batch
        return jnp.mean((xx @ p["w"] + p["b"] - yy) ** 2)

    return params, (x, y), loss_fn


def test_descent(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4)
    p = jax.tree.map(jnp.copy, params)
    losses = []
    for t in range(150):
        p, aux = mezo_step(loss_fn, p, batch, jnp.uint32(t), cfg)
        losses.append(float(aux.loss))
    assert losses[-1] < 0.6 * losses[0]


def test_perturb_restore_roundtrip(quad):
    params, _, _ = quad
    p1 = add_scaled_z(params, jnp.uint32(3), 0.5)
    p2 = add_scaled_z(p1, jnp.uint32(3), -0.5)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_vmapdir_matches_sequential(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4)
    pa, aux_a = mezo_step(loss_fn, jax.tree.map(jnp.copy, params), batch,
                          jnp.uint32(7), cfg)
    pb, aux_b = mezo_step_vmapdir(loss_fn, params, batch, jnp.uint32(7), cfg)
    # sequential walk accrues ~1e-4 float drift across directions
    np.testing.assert_allclose(np.asarray(aux_a.gs), np.asarray(aux_b.gs),
                               rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-3, atol=1e-4)


def test_spsa_correlates_with_gradient(quad):
    params, batch, loss_fn = quad
    g_true = jax.grad(loss_fn)(params, batch)
    cfg = MezoConfig(eps=1e-3, n_directions=64)
    g_est = spsa_gradient_estimate(loss_fn, params, batch, jnp.uint32(3),
                                   cfg)
    cos = jnp.vdot(g_true["w"], g_est["w"]) / (
        jnp.linalg.norm(g_true["w"]) * jnp.linalg.norm(g_est["w"]))
    assert float(cos) > 0.3


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_both_distributions_descend(quad, dist):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4, dist=dist)
    p = jax.tree.map(jnp.copy, params)
    l0 = float(loss_fn(p, batch))
    for t in range(100):
        p, aux = mezo_step(loss_fn, p, batch, jnp.uint32(t), cfg)
    assert float(aux.loss) < l0


def test_replay_reproduces_update(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=3)
    p1, aux = mezo_step_vmapdir(loss_fn, jax.tree.map(jnp.copy, params),
                                batch, jnp.uint32(11), cfg)
    p2 = replay_update(jax.tree.map(jnp.copy, params), aux.seed, aux.gs, cfg)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_direction_mask_drops_and_renormalizes(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4)
    cfg1 = MezoConfig(eps=1e-3, lr=1e-2, n_directions=1)
    mask = jnp.array([1.0, 0.0, 0.0, 0.0])
    pa, _ = mezo_step_vmapdir(loss_fn, jax.tree.map(jnp.copy, params),
                              batch, jnp.uint32(5), cfg, mask)
    # masked 4-direction step with only dir 0 == 1-direction step
    pb, _ = mezo_step_vmapdir(loss_fn, jax.tree.map(jnp.copy, params),
                              batch, jnp.uint32(5), cfg1)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-6)


def test_weight_decay_shrinks(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=1, weight_decay=0.5)
    p, _ = mezo_step(loss_fn, jax.tree.map(jnp.copy, params), batch,
                     jnp.uint32(0), cfg)
    assert float(jnp.linalg.norm(p["w"])) < float(
        jnp.linalg.norm(params["w"])) + 0.1


def test_kernel_path_matches_jnp_path(quad):
    params, batch, loss_fn = quad
    # pad w to kernel-eligible shape
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (256, 256))}

    def loss2(p, b):
        return jnp.sum(p["w"] ** 2) * 1e-4

    cfg_a = MezoConfig(eps=1e-3, lr=1e-2, use_kernel=False)
    cfg_b = MezoConfig(eps=1e-3, lr=1e-2, use_kernel=True)
    pa, _ = mezo_step(loss2, jax.tree.map(jnp.copy, params), None,
                      jnp.uint32(0), cfg_a)
    pb, _ = mezo_step(loss2, jax.tree.map(jnp.copy, params), None,
                      jnp.uint32(0), cfg_b)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-5, atol=1e-6)


def test_momentum_step_descends_and_beats_plain(quad):
    from repro.core import mezo_momentum_step, momentum_history_init
    params, batch, loss_fn = quad
    cfg_m = MezoConfig(eps=1e-3, lr=5e-3, n_directions=2, momentum=0.9,
                       momentum_window=8)
    cfg_p = MezoConfig(eps=1e-3, lr=5e-3, n_directions=2)

    p_m = jax.tree.map(jnp.copy, params)
    hist = momentum_history_init(cfg_m)
    losses_m = []
    for t in range(120):
        p_m, aux, hist = mezo_momentum_step(loss_fn, p_m, batch,
                                            jnp.uint32(t), cfg_m, hist)
        losses_m.append(float(aux.loss))

    p_p = jax.tree.map(jnp.copy, params)
    losses_p = []
    for t in range(120):
        p_p, aux = mezo_step(loss_fn, p_p, batch, jnp.uint32(t), cfg_p)
        losses_p.append(float(aux.loss))

    assert losses_m[-1] < losses_m[0]
    # momentum should at least match plain ZO-SGD on a quadratic
    assert np.mean(losses_m[-10:]) <= np.mean(losses_p[-10:]) * 1.25


def test_momentum_beta0_matches_plain(quad):
    """beta=0 momentum == plain step (weights collapse to newest-only)."""
    from repro.core import mezo_momentum_step, momentum_history_init
    params, batch, loss_fn = quad
    cfg0 = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, momentum=0.0,
                      momentum_window=4)
    hist = momentum_history_init(cfg0)
    pa, _, _ = mezo_momentum_step(loss_fn, jax.tree.map(jnp.copy, params),
                                  batch, jnp.uint32(3), cfg0, hist)
    pb, _ = mezo_step_vmapdir(loss_fn, jax.tree.map(jnp.copy, params),
                              batch, jnp.uint32(3), cfg0)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-6, atol=1e-7)
