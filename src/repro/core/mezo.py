"""MeZO: memory-efficient zeroth-order fine-tuning (PocketLLM's method).

Implements SPSA (Spall 1992) with MeZO's seed-replay storage trick
(Malladi et al. 2024), as adopted by PocketLLM for on-device fine-tuning:

    z ~ RNG(seed)          (regenerated, never stored)
    l+ = L(theta + eps z);  l- = L(theta - eps z)
    g  = (l+ - l-) / (2 eps)
    theta <- theta - lr * g * z

The step machinery itself lives in :mod:`repro.core.engine` as a
composable estimator×update strategy matrix; this module keeps the
historical step-function entry points as thin wrappers over registered
strategies, plus the standalone replay / analysis helpers:

* ``mezo_step``         -> strategy ``walk + sgd``    ("mezo")
* ``mezo_step_vmapdir`` -> strategy ``vmapdir + sgd`` ("mezo-parallel")
* ``mezo_step_fused``   -> strategy ``fused + sgd``   ("mezo-fused")
* ``mezo_momentum_step``-> strategy ``vmapdir + momentum``

All return the new params plus a :class:`MezoAux` record whose
``(seed, gs)`` pair is exactly what the replay-log checkpointer persists
(~12 bytes/step/direction) -- see repro/checkpoint/replay_log.py. Every
strategy shares the engine's f32 update tail, so the replay log is
interchangeable across them (bit-exact for the pristine-base-point
estimators ``vmapdir`` / ``fused``).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import rng as zrng
from repro.core.engine import (  # noqa: F401  (re-exported back-compat API)
    MezoAux, MezoConfig, TrainState, _apply_direction_updates, _decay,
    _direction_coeffs, build_strategy, get_strategy, momentum_history_init)
from repro.core.engine import LossFn, PyTree, SGD
from repro.core.perturb import add_scaled_z


def mezo_step(loss_fn: LossFn, params: PyTree, batch: Any, seed,
              cfg: MezoConfig, direction_mask=None):
    """Paper-faithful sequential MeZO step (in-place walk, donated params).

    direction_mask: optional (K,) 0/1 floats -- straggler mitigation drops
    late directions; the update renormalizes over survivors (an unbiased
    lower-sample SPSA estimate, unique to ZO: no gradient shard is lost).
    """
    strat = get_strategy("mezo")
    state, aux = strat.step(loss_fn, strat.init_state(params, cfg), batch,
                            seed, cfg, direction_mask)
    return state.params, aux


def mezo_step_vmapdir(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                      cfg: MezoConfig, direction_mask=None):
    """Direction-parallel MeZO step (strategy ``vmapdir + sgd``)."""
    strat = get_strategy("mezo-parallel")
    state, aux = strat.step(loss_fn, strat.init_state(params, cfg), batch,
                            seed, cfg, direction_mask)
    return state.params, aux


def mezo_step_fused(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                    cfg: MezoConfig, direction_mask=None):
    """Fused perturbed-forward MeZO step: 0 param sweeps per direction.

    ``loss_fn`` must accept a ``perturb=`` keyword (models built by
    repro.models.build_model do).
    """
    strat = get_strategy("mezo-fused")
    state, aux = strat.step(loss_fn, strat.init_state(params, cfg), batch,
                            seed, cfg, direction_mask)
    return state.params, aux


def mezo_momentum_step(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                       cfg: MezoConfig, hist):
    """ZO-momentum step (strategy ``vmapdir + momentum``).

    hist: the truncated seed-replay window from
    :func:`momentum_history_init` (or the previous call's return).
    Returns (params, aux, new_hist). Pre-engine histories without the
    per-entry ``coeffs`` row are upgraded with the ``-lr/K`` coefficient
    the old step function applied to every row (g=0 rows stay no-ops).
    """
    if "coeffs" not in hist:
        kk = hist["gs"].shape[1]
        hist = dict(hist, coeffs=jnp.full_like(
            hist["gs"], -jnp.float32(cfg.lr) / kk))
    strat = build_strategy("vmapdir", "momentum")
    state = TrainState(params=params, step=jnp.uint32(0), opt=hist)
    state, aux = strat.step(loss_fn, state, batch, seed, cfg)
    return state.params, aux, state.opt


def replay_update(params: PyTree, seed, gs, cfg: MezoConfig,
                  direction_mask=None):
    """Re-apply a logged step's update from its (seed, gs) record.

    This is the recovery path of the replay-log checkpointer: a crashed
    worker reconstructs theta_t from theta_0 and the scalar log at memory
    bandwidth, with zero forward passes. It *is* the engine's sgd update
    rule -- identical f32 arithmetic to the live step (including the f32
    ``lr * weight_decay`` coefficient), hence bit-exact replay for the
    pristine-base-point estimators. ``direction_mask`` is the logged
    straggler mask of the step, so replay renormalizes over the same
    surviving directions.
    """
    params, _ = SGD.update_fn(params, {}, seed, gs, direction_mask, cfg)
    return params


def spsa_gradient_estimate(loss_fn: LossFn, params: PyTree, batch: Any,
                           seed, cfg: MezoConfig) -> PyTree:
    """Materialized SPSA gradient estimate: mean_k g_k * z_k.

    Only for tests / analysis -- production paths never materialize z.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)

    def est(k):
        s = zrng.fold_seed(seed, k)
        lp = loss_fn(add_scaled_z(params, s, eps, dist=cfg.dist), batch)
        lm = loss_fn(add_scaled_z(params, s, -eps, dist=cfg.dist), batch)
        g = (lp - lm) / (2.0 * eps)
        zero = jax.tree.map(jnp.zeros_like, params)
        return add_scaled_z(zero, s, g, dist=cfg.dist)

    grads = [est(jnp.uint32(k)) for k in range(cfg.n_directions)]
    return jax.tree.map(lambda *xs: sum(xs) / len(xs), *grads)
