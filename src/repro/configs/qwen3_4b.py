"""qwen3-4b [dense]: qk_norm + GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728, vocab=151936,
        act="swiglu", norm="rmsnorm", qk_norm=True, pos="rope",
        rope_theta=1e6, max_seq=32768, tie_embeddings=True)
