"""Quickstart: PocketLLM's claim in one file.

Fine-tunes a reduced OPT-family model twice on the same synthetic data:
once with MeZO (derivative-free, 2 forwards/step, no optimizer state) and
once with Adam, reporting loss descent and the *state memory* each method
needs -- the paper's Table 1 contrast in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batches
from repro.optim.adam import AdamConfig
from repro.runtime import Trainer, TrainerConfig


def state_bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def main():
    cfg = get_config("opt-1.3b").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab=128)
    steps, batch, seq = 100, 8, 32

    runs = {}
    for opt in ("mezo", "adam"):
        tc = TrainerConfig(
            optimizer=opt,
            mezo=MezoConfig(eps=1e-2, lr=1e-2, n_directions=8),
            adam=AdamConfig(lr=1e-3),
            n_steps=steps, log_every=20)
        tr = Trainer(cfg, tc, lm_batches(batch, seq, cfg.vocab, seed=1))
        tr.train()
        runs[opt] = tr.losses

    params = Trainer(cfg, TrainerConfig(), iter(())).init_params()
    p_bytes = state_bytes(params)
    from repro.optim.adam import adam_init
    a_bytes = state_bytes(adam_init(params))

    print("\n=== PocketLLM quickstart ===")
    for opt, losses in runs.items():
        print(f"{opt:5s}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({len(losses)} steps)")
    print(f"\ntrain-state memory beyond params ({p_bytes/1e6:.1f} MB):")
    print(f"  mezo: 0.0 MB (z is regenerated from a seed; no grads, "
          f"no moments)")
    print(f"  adam: {a_bytes/1e6:.1f} MB (fp32 moments) + gradient buffer "
          f"+ activations for backprop")
    import numpy as np
    first = np.mean(runs["mezo"][:10])
    last = np.mean(runs["mezo"][-10:])
    assert last < first, "MeZO should descend"


if __name__ == "__main__":
    main()
