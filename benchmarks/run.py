"""Benchmark orchestrator -- one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per measurement) and
writes artifacts under experiments/bench/.

  PYTHONPATH=src python -m benchmarks.run \
      [--only table1|table2|table3|table4|fig1|roofline]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig1_loss, roofline, table1_memory,
                            table2_walltime, table3_serving,
                            table4_multitenant, table5_fleet)
    mods = {
        "table1": table1_memory,
        "table2": table2_walltime,
        "table3": table3_serving,
        "table4": table4_multitenant,
        "table5": table5_fleet,
        "fig1": fig1_loss,
        "roofline": roofline,
    }
    if args.only:
        mods = {args.only: mods[args.only]}

    print("name,us_per_call,derived")
    failed = 0
    for name, mod in mods.items():
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
