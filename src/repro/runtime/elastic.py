"""Elastic scaling for ZO training.

Because params are replicated across the ``pod`` axis and cross-pod state
is only the per-step (seed, gs) scalars, pods joining or leaving changes
*nothing* about parameter sharding -- only the direction count K. Elastic
events therefore cost:

  * pod join:  broadcast params into the new pod (one transfer), K += k
  * pod leave: K -= k, continue same step (ZO drop-direction semantics)

``elastic_mesh`` rebuilds the mesh for the current device count;
``remesh_params`` moves live params onto it (a device_put resharding; for
a same-(data,model)-topology change this is pod-broadcast only).
"""

from __future__ import annotations

import warnings
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models import sharding as shd

PyTree = Any


def mesh_shape_for(n: int, model_parallel: int, data_parallel: int
                   ) -> Tuple[int, int, int]:
    """(pods, data, model) mesh shape for ``n`` live devices.

    Keeps the intra-pod (data, model) topology fixed when at least one
    full pod's devices remain, absorbing count changes into the pod
    axis; otherwise degrades to one partial pod (model axis kept, data
    axis shrunk). Devices that don't fill the shape are *stranded* --
    excluded from the mesh, silently contributing nothing -- so any
    remainder is warned about by name rather than dropped quietly.
    """
    per_pod = model_parallel * data_parallel
    if n >= per_pod:
        shape = (n // per_pod, data_parallel, model_parallel)
    else:
        dp = max(1, n // model_parallel)
        if dp * model_parallel > n:
            model_parallel, dp = n, 1
        shape = (1, dp, model_parallel)
    used = int(np.prod(shape))
    if used < n:
        warnings.warn(
            f"elastic_mesh: stranding {n - used} of {n} devices (mesh "
            f"shape {shape} uses {used}; pod size "
            f"{per_pod} = {data_parallel} data x {model_parallel} "
            f"model) -- they will sit idle until the next resize",
            RuntimeWarning, stacklevel=3)
    return shape


def elastic_mesh(devices=None, model_parallel: int = 16,
                 data_parallel: int = 16):
    """Mesh for however many devices are currently alive.

    Keeps the intra-pod (data, model) topology fixed (so param shardings
    stay valid) and absorbs device-count changes into the pod axis.
    Falls back to shrinking data_parallel when fewer than one pod's
    devices remain (degraded single-pod mode). Devices beyond the last
    full pod are stranded with a warning (``mesh_shape_for``).
    """
    devices = np.asarray(devices if devices is not None else jax.devices())
    shape = mesh_shape_for(devices.size, model_parallel, data_parallel)
    devs = devices[: int(np.prod(shape))].reshape(shape)
    return Mesh(devs, ("pod", "data", "model"))


def remesh_params(params: PyTree, new_mesh: Mesh) -> PyTree:
    """Reshard live params onto a new mesh (pod join/leave)."""
    specs = shd.spec_tree(params)
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(new_mesh, s)),
        params, specs)
