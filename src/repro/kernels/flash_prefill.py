"""Chunked prefill attention into a paged KV cache (Pallas TPU + jnp
reference) -- the many-token-query sibling of flash_decode/flash_verify.

Chunked prefill admits a prompt C tokens at a time straight into the
slot's reserved pages: chunk offset c of slot b sits at logical position
``pos[b] + c`` and may attend to every cached position ``<= pos[b] + c``
-- the earlier prompt chunks already resident in the pool, plus causal
masking *inside* the chunk. The chunk's own K/V has been scattered into
the slot's pages by the caller before the read (exactly the verify
kernel's contract), so the kernel is pure page reads and no dense B=1
prompt cache ever exists.

Where flash_verify spends a grid dimension per window offset (right for
the W = k+1 <= ~5 speculative windows), this kernel keeps the whole
C-token chunk resident in VMEM per (slot, kv head) and sweeps the pages
once: grid (B, KV, n_live), q block (1, 1, C, G, hd), scores
(C*G, page_size) per tile with per-row causal limits, online-softmax
partials (acc, m, l) sized (C*G, ...) in VMEM scratch. One scratch
lifetime per (slot, kv head) instead of per (slot, kv head, offset) --
C times fewer page sweeps than routing a chunk through the verify grid.

Layout: q (B, C, H, hd) -- C chunk tokens per slot; k/v pools
(n_pages, page_size, KV, hd); pages (B, n_live) physical page ids;
pos (B,) each slot's chunk-start position. GQA: the G = H//KV query
heads of one KV head share a tile.

``prefill_attn_ref`` is the pure-jnp oracle and the non-TPU hot path;
at C=1 it degenerates to the same math as ``paged_attn_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import check_head_dim

_NEG_INF = -1e30


def _prefill_kernel(pages_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, ps, n_live, c, g, scale):
    bi = pl.program_id(0)
    pp = pl.program_id(2)

    @pl.when(pp == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # the page is live iff the chunk's LAST row can see it; per-row
    # masking below handles earlier rows' tighter causal limits
    pos0 = pos_ref[bi]
    live = pp * ps <= pos0 + (c - 1)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32).reshape(c * g, -1) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (ps, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        k_pos = pp * ps + jax.lax.broadcasted_iota(
            jnp.int32, (1, ps), 1)                           # (1, ps)
        # row r of the (C*G)-row tile is chunk offset r // g: it attends
        # through pos0 + r//g (earlier chunks + causal inside the chunk)
        q_pos = pos0 + jax.lax.broadcasted_iota(
            jnp.int32, (c * g, 1), 0) // g                   # (C*G, 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)           # (C*G, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(pp == n_live - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).reshape(
            o_ref.shape[2:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_prefill(q, k_pages, v_pages, pages, pos, *,
                  interpret: bool = False):
    """q: (B, C, H, hd); k/v pools: (NP, ps, KV, hd); pages: (B, n_live)
    int32 physical page ids; pos: (B,) int32 -> (B, C, H, hd).

    Chunk offset c of slot b reads positions <= pos[b] + c; everything
    later (the rest of the chunk, the slot's dead tail, trash-page table
    entries) is masked out. The table must cover pos + C - 1 -- the
    admission reservation guarantees the pages exist.
    """
    b, c, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    g = h // kvh
    n_live = pages.shape[1]
    check_head_dim(hd, interpret=interpret, kernel="flash_prefill")
    qg = q.reshape(b, c, kvh, g, hd).transpose(0, 2, 1, 3, 4)

    def qmap(bi, kv, pp, pages_ref, pos_ref):
        return (bi, kv, 0, 0, 0)

    def kvmap(bi, kv, pp, pages_ref, pos_ref):
        return (pages_ref[bi, pp], 0, kv, 0)

    kern = functools.partial(_prefill_kernel, ps=ps, n_live=n_live,
                             c=c, g=g, scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # pages, pos
        grid=(b, kvh, n_live),
        in_specs=[
            pl.BlockSpec((1, 1, c, g, hd), qmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, c, g, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((c * g, hd), jnp.float32),
            pltpu.VMEM((c * g,), jnp.float32),
            pltpu.VMEM((c * g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, c, g, hd), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), pos.astype(jnp.int32), qg, k_pages, v_pages)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, hd)


def prefill_attn_ref(q, k_pages, v_pages, pages, pos):
    """jnp oracle / non-TPU hot path: gather the live pages into logical
    order and run masked GQA attention with a per-(slot, offset) limit
    ``k_pos <= pos + c`` -- flash_decode's dead-tail skip plus causal
    masking inside the chunk, expressed as one 3-D kv_mask."""
    b, c, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    n_live = pages.shape[1]
    kk = k_pages[pages].reshape(b, n_live * ps, kvh, hd)
    vv = v_pages[pages].reshape(b, n_live * ps, kvh, hd)
    qpos = pos[:, None] + jnp.arange(c)[None, :]             # (B, C)
    valid = jnp.arange(n_live * ps)[None, None, :] <= qpos[:, :, None]
    from repro.models.layers import attention
    return attention(q, kk, vv, causal=False, kv_mask=valid, chunk=0)
