"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8 + 1 shared.
[arXiv:2501.kimi2; unverified paper-table]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, head_dim=112, d_ff=2048, vocab=163840,
        act="swiglu", norm="rmsnorm", pos="rope", rope_theta=5e4,
        n_experts=384, topk=8, expert_dff=2048, n_shared_experts=1,
        capacity_factor=1.25, fsdp_params=True, moe_ep=True, max_seq=32768)
