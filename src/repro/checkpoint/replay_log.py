"""Replay-log checkpointing -- ZO-native incremental checkpoints.

A MeZO trajectory is fully determined by (theta_0, [(seed_t, gs_t)]):
the update at step t is   theta -= lr/K * sum_k gs_t[k] * z(seed_t, k),
and z is regenerated from the seed. So instead of flushing terabytes of
params every N steps, we append ~(4 + 4K) bytes per step to a log and
snapshot full params only rarely. Recovery = load nearest snapshot +
``repro.core.mezo.replay_update`` over the tail: memory-bandwidth-bound,
zero forward passes. Bit-exact for the ``mezo_step_vmapdir`` path (same
update arithmetic on pristine params); for the in-place-walk ``mezo_step``
path, exact up to the walk's float roundoff drift (~1e-5 abs), which the
walk itself incurs anyway.

This is a capability *derivative-free* training gets for free and
derivative-based training fundamentally cannot have (gradients depend on
data); it is the fault-tolerance centerpiece of this framework
(DESIGN.md Sec 2).

Format: one JSONL line per step {"step","seed","gs","lr","eps"} -- tiny,
append-only, human-debuggable. fsync'd per append by default.
"""

from __future__ import annotations

import json
import os
import warnings
from typing import Iterator, List, Optional, Tuple

import numpy as np


class ReplayLog:
    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._seal_torn_tail(path)
        self._f = open(path, "a", buffering=1)

    @staticmethod
    def _seal_torn_tail(path: str):
        """A crash mid-append can leave a torn final line with NO
        newline; appending the restart's retried record would glue onto
        it and corrupt *both* lines. Seal the tear before appending."""
        try:
            with open(path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                torn = f.read(1) != b"\n"
        except (FileNotFoundError, OSError):
            return                        # missing or empty file
        if torn:
            with open(path, "ab") as f:
                f.write(b"\n")

    def append(self, step: int, seed, gs, lr: float, eps: float,
               mask=None, staleness=None):
        """``mask``: the step's straggler direction_mask, recorded so
        replay renormalizes over the same survivors the live update did.
        ``staleness``: for async (fleet) runs, the number of updates
        applied between the worker's params snapshot and this apply --
        replay scales the update by ``staleness_decay ** staleness``
        exactly as the live coordinator did."""
        rec = {"step": int(step), "seed": int(np.asarray(seed)),
               "gs": np.asarray(gs, np.float32).reshape(-1).tolist(),
               "lr": float(lr), "eps": float(eps)}
        if mask is not None:
            rec["mask"] = np.asarray(mask, np.float32).reshape(-1).tolist()
        if staleness is not None:
            rec["staleness"] = int(staleness)
        self._f.write(json.dumps(rec) + "\n")
        if self.fsync:
            self._f.flush()
            os.fsync(self._f.fileno())

    def close(self):
        self._f.close()

    @staticmethod
    def read(path: str, after_step: Optional[int] = None
             ) -> List[dict]:
        """Records with step > after_step, in order, tolerating corrupt
        lines (crash mid-append). A torn write is usually the tail, but a
        crash-then-restart appends *past* it -- so bad lines are skipped,
        not treated as end-of-log, and the retried step dedups below.
        Drops are counted and reported in one warning."""
        out, dropped = [], 0
        if not os.path.exists(path):
            return out
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    dropped += 1
                    continue
                if not isinstance(rec, dict) or "step" not in rec:
                    dropped += 1     # parseable junk (e.g. a bare number)
                    continue
                if after_step is None or rec["step"] > after_step:
                    out.append(rec)
        if dropped:
            warnings.warn(
                f"ReplayLog.read({path}): dropped {dropped} corrupt "
                f"line(s) (torn append); kept {len(out)} valid record(s)",
                RuntimeWarning, stacklevel=2)
        # de-duplicate on step (a retried step may be appended twice).
        # A benign retry repeats the record verbatim; async delivery can
        # also produce a *divergent* retry -- same step, different
        # seed/gs (e.g. a re-issued lease evaluated at a newer params
        # version). First-applied wins either way, but a divergent
        # duplicate is surfaced: it means two writers raced the log.
        kept, dedup, conflicts = {}, [], set()
        for r in out:
            prev = kept.get(r["step"])
            if prev is None:
                kept[r["step"]] = r
                dedup.append(r)
            elif (prev.get("seed") != r.get("seed")
                  or prev.get("gs") != r.get("gs")):
                conflicts.add(r["step"])
        if conflicts:
            shown = sorted(conflicts)
            warnings.warn(
                f"ReplayLog.read({path}): {len(conflicts)} conflicting "
                f"duplicate step(s) {shown[:8]}"
                f"{'...' if len(shown) > 8 else ''} carry different "
                f"seed/gs (divergent retry); kept the first-applied "
                f"record per step", RuntimeWarning, stacklevel=2)
        return dedup


def replay_into(params, records: List[dict], cfg) -> Tuple[object, int]:
    """Apply logged updates in order. Returns (params, last_step).

    File order IS application order: async (fleet) logs carry step ids
    out of order -- the step field keys dedup/resume, never reordering.
    A record bearing ``staleness`` replays through the ``stale-sgd``
    update rule (decay ``cfg.staleness_decay ** staleness`` folded into
    the direction coefficients); the fleet coordinator applies its live
    updates through this very function, so live-vs-replay is
    bit-identical by construction.
    """
    import dataclasses

    from repro.core.mezo import replay_update
    last = -1
    for rec in records:
        c = dataclasses.replace(cfg, lr=rec["lr"], eps=rec["eps"])
        mask = rec.get("mask")
        mask = None if mask is None else np.asarray(mask, np.float32)
        stale = rec.get("staleness")
        if stale is None:
            params = replay_update(params, np.uint32(rec["seed"]),
                                   np.asarray(rec["gs"], np.float32), c,
                                   direction_mask=mask)
        else:
            from repro.core.engine import STALE_SGD
            params, _ = STALE_SGD.update_fn(
                params, {}, np.uint32(rec["seed"]),
                np.asarray(rec["gs"], np.float32), mask, c,
                staleness=stale)
        last = rec["step"]
    return params, last
