"""Data pipeline: planted structure, step-indexed determinism, prefetch."""

import numpy as np

from repro.data import DataPipeline, lm_batches, sst2_batches
from repro.data.synthetic import synthetic_lm_corpus, synthetic_sst2


def test_markov_corpus_has_learnable_structure():
    toks = synthetic_lm_corpus(20000, 64, seed=0, peakiness=0.85)
    # successor determinism: most common next-token should dominate
    follows = {}
    for a, b in zip(toks[:-1], toks[1:]):
        follows.setdefault(int(a), []).append(int(b))
    hit = 0
    tot = 0
    for a, bs in follows.items():
        if len(bs) < 10:
            continue
        vals, counts = np.unique(bs, return_counts=True)
        hit += counts.max()
        tot += len(bs)
    assert hit / tot > 0.7


def test_lm_batches_step_indexed_determinism():
    a = list(lm_batches(2, 8, 64, seed=1, n_steps=5))
    b = list(lm_batches(2, 8, 64, seed=1, n_steps=5, start_step=3))
    np.testing.assert_array_equal(a[3]["tokens"], b[0]["tokens"])
    np.testing.assert_array_equal(a[4]["targets"], b[1]["targets"])


def test_targets_are_shifted_tokens():
    b = next(lm_batches(2, 8, 64, seed=2))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_sst2_labels_balanced_and_planted():
    toks, labels = synthetic_sst2(512, 16, 128, seed=0)
    assert toks.shape == (512, 16)
    assert 0.4 < labels.mean() < 0.6
    assert (toks[:, 0] == 0).all()  # CLS


def test_pipeline_prefetch_and_errors():
    pipe = DataPipeline(lm_batches(2, 8, 64, seed=0, n_steps=3))
    batches = list(pipe)
    assert len(batches) == 3

    def boom():
        yield {"x": np.zeros(2)}
        raise ValueError("source died")

    pipe = DataPipeline(boom())
    next(pipe)
    try:
        next(pipe)
        raise AssertionError("should raise")
    except ValueError:
        pass
