"""Core: PocketLLM's derivative-free (zeroth-order) fine-tuning engine."""

from repro.core.mezo import (MezoAux, MezoConfig, mezo_momentum_step,
                             mezo_step, mezo_step_fused, mezo_step_vmapdir,
                             momentum_history_init, replay_update,
                             spsa_gradient_estimate)
from repro.core.perturb import add_scaled_z, dot_with_z, leaf_salts
from repro.core.perturb_ctx import PerturbCtx
from repro.core.rng import fold_seed, gaussian_field, rademacher_field, z_field

__all__ = [
    "MezoAux", "MezoConfig", "PerturbCtx", "mezo_momentum_step",
    "momentum_history_init", "mezo_step", "mezo_step_fused",
    "mezo_step_vmapdir",
    "replay_update", "spsa_gradient_estimate", "add_scaled_z", "dot_with_z",
    "leaf_salts", "fold_seed", "gaussian_field", "rademacher_field", "z_field",
]
