"""Per-slot token sampling: greedy and seeded top-k.

The engine owns ONE PRNG key and splits it per decode step; the step key
is folded with the slot index so every slot draws from an independent
stream. This replaces the old ``PRNGKey(loop_index)`` pattern, which
rebuilt the key from the step counter -- identical across runs and
correlated across requests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnums=(1,))
def step_keys(key, n_slots: int):
    """Advance the engine key one step; returns (new_key, (n_slots, ...)
    per-slot keys)."""
    key, sub = jax.random.split(key)
    slot_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        sub, jnp.arange(n_slots, dtype=jnp.uint32))
    return key, slot_keys


def greedy(logits):
    """logits: (B, V) -> (B,) argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnums=(2,))
def sample_topk(keys, logits, k: int, temperature=1.0):
    """Seeded top-k sampling, vectorized over slots.

    keys: (B, ...) per-slot keys (from :func:`step_keys`); logits: (B, V).
    Renormalizes over the k largest logits, scaled by ``temperature``.
    """
    k = max(1, min(k, logits.shape[-1]))
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    t = jnp.maximum(jnp.float32(temperature), 1e-6)

    def one(kk, vv, ii):
        return ii[jax.random.categorical(kk, vv / t)]

    return jax.vmap(one)(keys, vals, idx).astype(jnp.int32)


@partial(jax.jit, static_argnums=(3,))
def spec_accept(key, draft, logits, k: int, temperature=1.0):
    """Speculative rejection sampling against a *greedy* draft.

    draft: (d,) greedily-drafted tokens (d >= 1); logits: (d+1, V) target
    logits at the d+1 window positions (same top-k/temperature truncation
    as :func:`sample_topk` defines the target distribution p_i). The
    draft distribution is the one-hot q_i = delta(draft[i]), so the
    standard accept rule (accept w.p. min(1, p/q)) reduces to: accept
    draft[i] with probability p_i(draft[i]); on the first rejection
    resample from the residual norm(max(p_i - q_i, 0)) -- p_i with the
    draft token zeroed out; if every draft token is accepted, sample the
    bonus token from p_d unmodified. Either way the emitted sequence is
    distributed exactly as d+1 sequential draws from the target.

    Returns (n_accepted, next_token): commit draft[:n_accepted] followed
    by next_token.
    """
    d = draft.shape[0]
    k = max(1, min(k, logits.shape[-1]))
    t = jnp.maximum(jnp.float32(temperature), 1e-6)
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    pk = jax.nn.softmax(vals / t, axis=-1)              # (d+1, k)
    probs = jax.vmap(lambda ix, pr: jnp.zeros(
        logits.shape[-1], jnp.float32).at[ix].set(pr))(idx, pk)
    ukey, skey = jax.random.split(key)
    p_draft = jnp.take_along_axis(probs[:d], draft[:, None], axis=1)[:, 0]
    accept = jax.random.uniform(ukey, (d,)) < p_draft
    n = jnp.sum(jnp.cumprod(accept.astype(jnp.int32)))  # accepted prefix
    row = probs[jnp.minimum(n, d)]                      # resample source
    zeroed = row.at[draft[jnp.minimum(n, d - 1)]].set(0.0)
    resid = jnp.where(n < d, zeroed, row)               # bonus: full p_d
    resid = jnp.where(resid.sum() > 0, resid, row)      # numeric fallback
    nxt = jax.random.categorical(
        skey, jnp.where(resid > 0, jnp.log(resid), -jnp.inf))
    return n.astype(jnp.int32), nxt.astype(jnp.int32)
