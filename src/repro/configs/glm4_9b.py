"""glm4-9b [dense]: RoPE + GQA kv=2. [hf:THUDM/glm-4-9b; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552,
        act="swiglu", norm="rmsnorm", pos="rope", rope_pct=0.5,
        max_seq=32768)
