"""Family assembly over the block-registry runtime.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions:

  init(key)                          -> params
  forward(params, batch)             -> (logits, aux)   (train / prefill)
  loss(params, batch, perturb=...)   -> scalar          (the ZO objective)
  init_cache(bsz)                    -> StateCache pytree
  decode_step(params, cache, tok, pos) -> (logits, cache)
  prefill(params, cache, prompt)     -> (logits, cache)  (fused)

All five families share ONE implementation of forward / loss /
init_cache / decode_step / prefill -- the generic backbone engine in
:mod:`repro.models.runtime`, driven by a declarative :class:`ModelPlan`
assembled here from ``ModelConfig``. A family is just

  * a plan: which (norm, mixer) sublayers each layer holds, resolved
    against the block registry (``repro.models.blocks``), and
  * an init: how RNG keys route into each block's ``init`` (kept
    family-specific so parameter trees are bit-identical to the
    pre-registry layout -- existing checkpoints, replay logs, and leaf
    salts are untouched).

Because the engine threads ``PerturbCtx`` through every block uniformly,
the fused ZO perturbed forward works for every family -- no family
materializes a transient perturbed parameter copy in its loss path.

``prefill`` runs a whole (B, P) prompt in ONE call, writing cache
positions [0, P) and returning the next-token logits (B, 1, V) -- the
serving engine's replacement for P per-token ``decode_step`` dispatches.
``decode_step`` accepts ``pos`` as a scalar (whole batch at one
position) or as a (B,) vector (continuous batching). Layer stacks are
``lax.scan``-ed over stacked (L, ...) params so the HLO is O(1) in
depth -- essential for compiling 61-layer 1T-param configs.
"""

from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models import runtime as RT
from repro.models.config import ModelConfig
from repro.models.runtime import (AUX_LOSS_WEIGHT, ModelPlan, StackPlan,
                                  Sublayer, softmax_xent)

__all__ = ["Model", "build_model", "build_plan", "softmax_xent",
           "AUX_LOSS_WEIGHT"]

PyTree = Any


@dataclasses.dataclass(frozen=True, eq=False)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Optional[Callable] = None
    prefill: Optional[Callable] = None
    plan: Optional[ModelPlan] = None
    # paged serving: None when no sublayer has pageable state (rwkv6's
    # recurrent state never pages -- a "paged" engine then runs the
    # dense layout). Signature: init_paged_cache(bsz, n_pages,
    # page_size, max_len=None); decode_step takes pages=/write_mask=.
    init_paged_cache: Optional[Callable] = None
    # speculative decoding's exact scoring call over a paged cache:
    # verify_window(params, cache, toks (B, W), pos (B,), pages=,
    # write_mask=(B, W)) -> (logits (B, W, V), cache). None whenever
    # init_paged_cache is None (the verify window reads the page pool).
    verify_window: Optional[Callable] = None
    # chunked prefill straight into the page pool: prefill_chunk(params,
    # cache, toks (B, C), pos (B,), pages=, write_mask=) -> (logits
    # (B, C, V), cache) -- attention K/V written through the page table,
    # recurrent state advanced in place. None whenever init_paged_cache
    # is None (the chunk writes into the shared pool).
    prefill_chunk: Optional[Callable] = None


def _no_decode(*_args, **_kwargs):
    """Decode-path stub for encoder-only architectures."""
    raise ValueError("encoder-only arch has no decode path")


# ===========================================================================
# plans: which sublayers each family's layer holds


def _lm_plan(cfg: ModelConfig) -> ModelPlan:
    """Decoder-only LM (dense / moe / vlm-backbone) and the encoder-only
    classifier: [attn, ffn] per layer."""
    ffn = "moe" if cfg.n_experts else "mlp"
    return ModelPlan(cfg, StackPlan("blocks", cfg.n_layers, (
        Sublayer("ln_attn", "attn", "attention"),
        Sublayer("ln_ffn", ffn, ffn))))


def _hybrid_plan(cfg: ModelConfig) -> ModelPlan:
    """Hybrid (jamba): super-blocks of ``block_len`` sublayers -- mamba
    everywhere except ``attn_index``, an FFN (MoE on odd sublayers when
    configured) after each mixer."""
    subs = []
    for i in range(cfg.block_len):
        if i == cfg.attn_index:
            subs.append(Sublayer(f"sub_{i}/ln", f"sub_{i}/attn", "attention"))
        else:
            subs.append(Sublayer(f"sub_{i}/ln", f"sub_{i}/mamba", "mamba"))
        ffn = "moe" if cfg.n_experts and i % 2 == 1 else "mlp"
        subs.append(Sublayer(f"sub_{i}/ln_ffn", f"sub_{i}/{ffn}", ffn))
    return ModelPlan(cfg, StackPlan("blocks", cfg.n_layers // cfg.block_len,
                                    tuple(subs)))


def _rwkv_plan(cfg: ModelConfig) -> ModelPlan:
    return ModelPlan(cfg, StackPlan("blocks", cfg.n_layers, (
        Sublayer("ln1", "tm", "rwkv_timemix"),
        Sublayer("ln2", "cm", "rwkv_channelmix"))))


def _encdec_plan(cfg: ModelConfig) -> ModelPlan:
    """Encoder-decoder (whisper): stub conv frontend -> enc_embeds in the
    batch; decoder = [self-attn, cross-attn, mlp] per layer."""
    enc = StackPlan("enc_blocks", cfg.enc_layers, (
        Sublayer("ln_attn", "attn", "attention", (("causal", False),)),
        Sublayer("ln_ffn", "mlp", "mlp")))
    dec = StackPlan("dec_blocks", cfg.dec_layers, (
        Sublayer("ln_self", "self", "attention", (("causal", True),)),
        Sublayer("ln_cross", "cross", "cross_attention"),
        Sublayer("ln_ffn", "mlp", "mlp")))
    return ModelPlan(cfg, dec, encoder=enc)


_PLANS = {"dense": _lm_plan, "moe": _lm_plan, "encoder": _lm_plan,
          "hybrid": _hybrid_plan, "ssm": _rwkv_plan, "encdec": _encdec_plan}


def build_plan(cfg: ModelConfig) -> ModelPlan:
    if cfg.family not in _PLANS:
        raise ValueError(f"unknown family {cfg.family}")
    return _PLANS[cfg.family](cfg)


# ===========================================================================
# inits: family-specific RNG-key routing into block inits. The exact
# split/fold sequences are load-bearing: they pin parameter trees
# bit-identical across refactors (golden parity suite).


def _lm_block_init(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln_attn": L.norm_init(cfg, k1),
         "attn": B.get_block("attention").init(cfg, k2),
         "ln_ffn": L.norm_init(cfg, k3)}
    if cfg.n_experts:
        p["moe"] = B.get_block("moe").init(cfg, k4)
    else:
        p["mlp"] = B.get_block("mlp").init(cfg, k4)
    return p


def _lm_init(cfg, key):
    ke, kb, kn, kh = jax.random.split(key, 4)
    blocks = jax.vmap(lambda k: _lm_block_init(cfg, k))(
        jax.random.split(kb, cfg.n_layers))
    p = {"embed": L.embed_init(cfg, ke), "blocks": blocks,
         "ln_f": L.norm_init(cfg, kn)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))
    if cfg.n_classes:
        p["cls_head"] = L.dense_init(kh, cfg.d_model, cfg.n_classes,
                                     jnp.float32, bias=True)
    return p


def _hybrid_block_init(cfg, key):
    nb = cfg.block_len
    ks = jax.random.split(key, 2 * nb)
    p = {}
    for i in range(nb):
        sub = {"ln": L.norm_init(cfg, ks[2 * i])}
        if i == cfg.attn_index:
            sub["attn"] = B.get_block("attention").init(cfg, ks[2 * i + 1])
        else:
            sub["mamba"] = B.get_block("mamba").init(cfg, ks[2 * i + 1])
        kf = jax.random.fold_in(ks[2 * i + 1], 7)
        sub["ln_ffn"] = L.norm_init(cfg, jax.random.fold_in(kf, 1))
        if cfg.n_experts and i % 2 == 1:
            sub["moe"] = B.get_block("moe").init(cfg, kf)
        else:
            sub["mlp"] = B.get_block("mlp").init(cfg, kf)
        p[f"sub_{i}"] = sub
    return p


def _hybrid_init(cfg, key):
    nb = cfg.n_layers // cfg.block_len
    ke, kb, kn, kh = jax.random.split(key, 4)
    blocks = jax.vmap(lambda k: _hybrid_block_init(cfg, k))(
        jax.random.split(kb, nb))
    return {"embed": L.embed_init(cfg, ke), "blocks": blocks,
            "ln_f": L.norm_init(cfg, kn),
            "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))}


def _rwkv_init(cfg, key):
    ke, kb, kn, kh = jax.random.split(key, 4)

    def block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"ln1": L.norm_init(cfg, k1),
                "tm": B.get_block("rwkv_timemix").init(cfg, k2),
                "ln2": L.norm_init(cfg, k3),
                "cm": B.get_block("rwkv_channelmix").init(cfg, k4)}

    blocks = jax.vmap(block)(jax.random.split(kb, cfg.n_layers))
    return {"embed": L.embed_init(cfg, ke), "blocks": blocks,
            "ln_f": L.norm_init(cfg, kn),
            "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, L._dt(cfg))}


def _encdec_init(cfg, key):
    ke, kenc, kdec, kn = jax.random.split(key, 4)
    attn_init = B.get_block("attention").init
    mlp_init = B.get_block("mlp").init

    def enc_block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"ln_attn": L.norm_init(cfg, k1), "attn": attn_init(cfg, k2),
                "ln_ffn": L.norm_init(cfg, k3), "mlp": mlp_init(cfg, k4)}

    def dec_block(k):
        k1, k2, k3, k4, k5, k6 = jax.random.split(k, 6)
        return {"ln_self": L.norm_init(cfg, k1), "self": attn_init(cfg, k2),
                "ln_cross": L.norm_init(cfg, k3), "cross": attn_init(cfg, k4),
                "ln_ffn": L.norm_init(cfg, k5), "mlp": mlp_init(cfg, k6)}

    return {
        "embed": L.embed_init(cfg, ke),
        "enc_blocks": jax.vmap(enc_block)(jax.random.split(kenc, cfg.enc_layers)),
        "dec_blocks": jax.vmap(dec_block)(jax.random.split(kdec, cfg.dec_layers)),
        "ln_enc": L.norm_init(cfg, kn),
        "ln_f": L.norm_init(cfg, jax.random.fold_in(kn, 1)),
    }


_INITS = {"dense": _lm_init, "moe": _lm_init, "encoder": _lm_init,
          "hybrid": _hybrid_init, "ssm": _rwkv_init, "encdec": _encdec_init}


# ===========================================================================
# the facade


@functools.lru_cache(maxsize=None)
def build_model(cfg: ModelConfig) -> Model:
    """Memoized on the (frozen, hashable) config: every caller holding
    the same config shares ONE Model instance, so the jitted serving /
    decode entry points traced against its bound functions hit the
    compilation cache across engines instead of re-tracing per engine
    (the dominant cost of the pre-paging decode baseline -- table3)."""
    plan = build_plan(cfg)
    dtype = L._dt(cfg)
    init = partial(_INITS[cfg.family], cfg)
    if cfg.family == "encoder":
        return Model(
            cfg=cfg, plan=plan, init=init,
            forward=partial(RT.forward, plan),
            loss=partial(RT.loss, plan),
            init_cache=_no_decode,
            decode_step=None,
        )
    return Model(
        cfg=cfg, plan=plan, init=init,
        forward=partial(RT.forward, plan),
        loss=partial(RT.loss, plan),
        init_cache=lambda bsz, max_len=None: RT.init_cache(
            plan, bsz, max_len or cfg.max_seq, dtype),
        decode_step=partial(RT.decode_step, plan),
        prefill=None if cfg.n_classes else partial(RT.prefill, plan),
        init_paged_cache=(
            (lambda bsz, n_pages, page_size, max_len=None:
             RT.init_paged_cache(plan, bsz, n_pages, page_size, dtype,
                                 max_len=max_len))
            if RT.plan_pages(plan) else None),
        verify_window=(partial(RT.verify_window, plan)
                       if RT.plan_pages(plan) else None),
        prefill_chunk=(partial(RT.prefill_chunk, plan)
                       if RT.plan_pages(plan) else None),
    )
