"""Bench-regression gate over the committed experiments/bench JSONs.

The benchmark modules (tables 1-4) each write a JSON artifact that is
committed with the PR that produced it. This check validates those
artifacts -- presence, schema, and the paper-level invariants each table
exists to demonstrate -- so a refactor that silently regresses a
headline claim (MeZO's memory edge, the fused prefill win, the
multi-tenant engine's batched speedup) fails CI even when no test
exercises the perf path.

Invariant thresholds are deliberately slack (absolute CPU numbers are
noisy across machines); what they pin is the *direction and rough
magnitude* of each table's claim:

  table1: MeZO inference-parity memory stays under Adam's
  table2: MeZO wall-clock/step stays under Adam's (bs8 arm)
  table3: fused prefill > 2x the per-token loop; adapter cache hits are
          orders-of-magnitude cheaper than cold replays
  table4: batched TrainEngine > 2x sequential user-steps/s (both arms);
          int8 resident base stays smaller than one user's f32 delta
  table5: async fleet's modeled steps/s scales with worker count despite
          20% injected stragglers; eval loss still descends under
          asynchrony; every arm's staleness-bearing replay log
          reconstructs live params bit-exactly (hard gate)

  PYTHONPATH=src python -m benchmarks.check_regression [--dir DIR]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

FAILURES: list = []


def _check(name: str, ok: bool, detail: str):
    print(f"  {'ok  ' if ok else 'FAIL'} {name}: {detail}")
    if not ok:
        FAILURES.append(name)


def _load(bench_dir: str, fname: str):
    path = os.path.join(bench_dir, fname)
    if not os.path.exists(path):
        _check(fname, False, "artifact missing (run benchmarks and commit)")
        return None
    with open(path) as f:
        try:
            return json.load(f)
        except json.JSONDecodeError as e:
            _check(fname, False, f"unparseable JSON: {e}")
            return None


def check_table1(bench_dir: str):
    t = _load(bench_dir, "table1_memory.json")
    if t is None:
        return
    for bs in ("bs8", "bs64"):
        mezo, adam = t.get(f"live/mezo/{bs}"), t.get(f"live/adam/{bs}")
        ok = mezo is not None and adam is not None and mezo < adam
        _check(f"table1/live_{bs}", ok,
               f"mezo {mezo} MB vs adam {adam} MB (mezo must be lower)")


def check_table2(bench_dir: str):
    t = _load(bench_dir, "table2_walltime.json")
    if t is None:
        return
    mezo, adam = t.get("mezo/bs8"), t.get("adam/bs8")
    ok = mezo is not None and adam is not None and mezo < adam
    _check("table2/step_bs8", ok,
           f"mezo {mezo} us vs adam {adam} us (mezo must be faster)")


def check_table3(bench_dir: str):
    t = _load(bench_dir, "table3_serving.json")
    if t is None:
        return
    pf = t.get("prefill", {})
    _check("table3/prefill_speedup", pf.get("speedup", 0) > 2.0,
           f"fused prefill {pf.get('speedup')}x over loop (need > 2x)")
    ad = t.get("adapter", {})
    cold, hit = ad.get("cold_s"), ad.get("hit_s")
    ok = cold is not None and hit is not None and hit < cold / 100
    _check("table3/adapter_cache", ok,
           f"cache hit {hit}s vs cold replay {cold}s (need > 100x)")
    # PR 7 headline: decode must stay >= 2x the pre-paging baseline
    # (99.96 tok/s committed with PR 6, same slots/model/gen shape),
    # with the paged engine bit-identical to unpaged and holding >= 2x
    # the resident slots at the same KV HBM budget.
    PRE_PAGING_DECODE_TPS = 99.96
    lg = t.get("decode_long", {})
    tps = lg.get("paged_tok_per_s", 0)
    _check("table3/decode_paged_tps",
           tps >= 2 * PRE_PAGING_DECODE_TPS,
           f"paged decode {tps:.0f} tok/s vs {PRE_PAGING_DECODE_TPS} "
           f"pre-paging baseline (need >= 2x)")
    _check("table3/decode_paged_parity",
           lg.get("paged_greedy_parity") is True,
           f"paged greedy tokens == unpaged: "
           f"{lg.get('paged_greedy_parity')}")
    rs = t.get("resident_slots", {})
    ratio = rs.get("slots_ratio", 0)
    _check("table3/resident_slots", ratio >= 2.0,
           f"{rs.get('paged_peak_active_slots')} paged slots vs "
           f"{rs.get('dense_slots')} dense at {rs.get('kv_budget_pages')} "
           f"KV pages = {ratio}x (need >= 2x)")
    # PR 8 headline: self-speculative decode (base drafts, base+delta
    # verifies over shared pages) must be exact AND faster -- greedy
    # bit-parity is a hard gate, throughput >= 1.3x the plain paged
    # multi-adapter run, with the acceptance rate actually reported.
    sp = t.get("decode_spec", {})
    _check("table3/decode_spec_parity",
           sp.get("greedy_parity") is True,
           f"speculative greedy tokens == plain: "
           f"{sp.get('greedy_parity')}")
    _check("table3/decode_spec_speedup", sp.get("speedup", 0) >= 1.3,
           f"spec {sp.get('spec_tok_per_s')} tok/s vs plain "
           f"{sp.get('plain_tok_per_s')} = {sp.get('speedup')}x "
           f"(need >= 1.3x)")
    ar = sp.get("accept_rate")
    _check("table3/decode_spec_accept_rate",
           ar is not None and 0.0 < ar <= 1.0,
           f"acceptance rate {ar} (must be reported and in (0, 1])")
    # PR 9 headline: chunked prefill must keep greedy bit-parity with
    # whole-prompt admission (hard) and cut the admission stall --
    # slot-seconds decoders sit idle during prefill work -- at least 2x
    # under the long-prompt-arrival mixed load, TTFT p99 reported.
    ml = t.get("mixed_load", {})
    _check("table3/mixed_load_parity",
           ml.get("greedy_parity") is True,
           f"chunked greedy tokens == whole-prompt: "
           f"{ml.get('greedy_parity')}")
    sr = ml.get("stall_ratio", 0)
    _check("table3/mixed_load_stall", sr >= 2.0,
           f"decode stall {ml.get('whole_decode_stall_s')}s whole vs "
           f"{ml.get('chunked_decode_stall_s')}s chunked = {sr:.1f}x "
           f"(need >= 2x)")
    p99 = ml.get("chunked_ttft_p99_ms")
    _check("table3/mixed_load_ttft",
           p99 is not None and p99 > 0
           and ml.get("whole_ttft_p99_ms") is not None,
           f"ttft p99 whole {ml.get('whole_ttft_p99_ms')}ms / chunked "
           f"{p99}ms (must be reported)")


def check_table4(bench_dir: str):
    t = _load(bench_dir, "table4_multitenant.json")
    if t is None:
        return
    for arm in ("f32", "int8"):
        a = t.get(arm, {})
        _check(f"table4/{arm}_speedup", a.get("speedup", 0) > 2.0,
               f"engine {a.get('engine_user_steps_per_s')} vs sequential "
               f"{a.get('seq_user_steps_per_s')} user-steps/s = "
               f"{a.get('speedup')}x (need > 2x)")
    q = t.get("int8", {})
    bb, db = q.get("base_bytes"), q.get("delta_bytes_per_user")
    ok = bb is not None and db is not None and 0 < bb < db
    _check("table4/int8_resident", ok,
           f"shared int8 base {bb} B vs f32 delta/user {db} B "
           f"(base must be the smaller resident share)")


def check_table5(bench_dir: str):
    t = _load(bench_dir, "table5_fleet.json")
    if t is None:
        return
    arms = t.get("arms", {})
    sps = {}
    for key in ("w1", "w4", "w16"):
        a = arms.get(key, {})
        sps[key] = a.get("virtual_steps_per_s", 0)
        # the replay-log contract is the subsystem's whole point: a
        # single non-bit-exact arm is a hard failure, not noise
        _check(f"table5/{key}_replay", a.get("replay_bitexact") is True,
               f"replay-from-log bit-exact: {a.get('replay_bitexact')}")
        drop = (a.get("eval_loss_init", 0) or 0) - \
               (a.get("eval_loss_final", 1e9) or 1e9)
        _check(f"table5/{key}_loss", drop > 0.02,
               f"held-out eval loss {a.get('eval_loss_init')} -> "
               f"{a.get('eval_loss_final')} (need > 0.02 drop under "
               f"asynchrony)")
    # modeled (virtual-time) throughput is deterministic, so the scaling
    # claim gates cleanly: thresholds still slack vs the ~2.9x / ~9x the
    # committed artifact shows, to survive scheduler evolution
    _check("table5/scaling_w4", sps["w4"] > 2.0 * sps["w1"],
           f"w4 {sps['w4']:.0f} vs w1 {sps['w1']:.0f} modeled steps/s "
           f"(need > 2x despite 20% stragglers)")
    _check("table5/scaling_w16", sps["w16"] > 5.0 * sps["w1"],
           f"w16 {sps['w16']:.0f} vs w1 {sps['w1']:.0f} modeled steps/s "
           f"(need > 5x despite 20% stragglers)")
    _check("table5/async_exercised",
           arms.get("w16", {}).get("max_staleness", 0) > 0,
           f"w16 max staleness {arms.get('w16', {}).get('max_staleness')}"
           f" (0 would mean the run serialized -- nothing async tested)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/bench",
                    help="directory holding the committed bench JSONs")
    args = ap.parse_args()
    print(f"[check_regression] validating artifacts under {args.dir}")
    for fn in (check_table1, check_table2, check_table3, check_table4,
               check_table5):
        fn(args.dir)
    if FAILURES:
        print(f"[check_regression] {len(FAILURES)} failure(s): "
              f"{', '.join(FAILURES)}")
        sys.exit(1)
    print("[check_regression] all bench invariants hold")


if __name__ == "__main__":
    main()
