"""Core: PocketLLM's derivative-free (zeroth-order) fine-tuning engine."""

from repro.core.engine import (DirectionEvaluator, TrainState, UpdateRule,
                               ZOStrategy, build_strategy, estimator_names,
                               get_strategy, register_estimator,
                               register_strategy, register_update_rule,
                               strategy_names, update_rule_names)
from repro.core.mezo import (MezoAux, MezoConfig, mezo_momentum_step,
                             mezo_step, mezo_step_fused, mezo_step_vmapdir,
                             momentum_history_init, replay_update,
                             spsa_gradient_estimate)
from repro.core.perturb import add_scaled_z, dot_with_z, leaf_salts
from repro.core.perturb_ctx import PerturbCtx
from repro.core.rng import fold_seed, gaussian_field, rademacher_field, z_field

__all__ = [
    "DirectionEvaluator", "MezoAux", "MezoConfig", "PerturbCtx",
    "TrainState", "UpdateRule", "ZOStrategy", "build_strategy",
    "estimator_names", "get_strategy", "mezo_momentum_step",
    "momentum_history_init", "mezo_step", "mezo_step_fused",
    "mezo_step_vmapdir", "register_estimator", "register_strategy",
    "register_update_rule", "replay_update", "spsa_gradient_estimate",
    "strategy_names", "update_rule_names", "add_scaled_z", "dot_with_z",
    "leaf_salts", "fold_seed", "gaussian_field", "rademacher_field",
    "z_field",
]
