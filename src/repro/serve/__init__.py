"""Personalized serving: ZO-adapter store + fused prefill +
continuous-batching decode (see docs/architecture.md, Serving)."""

from repro.serve.sampling import (greedy, sample_topk, spec_accept,
                                  step_keys)
from repro.serve.adapters import (AdapterStore, BASE_USER, ZOAdapter,
                                  tree_bytes)
from repro.serve.engine import (Completion, EngineStats, Request,
                                ServeEngine)

__all__ = [
    "AdapterStore", "BASE_USER", "Completion", "EngineStats", "Request",
    "ServeEngine", "ZOAdapter", "greedy", "sample_topk", "spec_accept",
    "step_keys", "tree_bytes",
]
