"""Continuous-batching decode engine over per-user ZO adapters.

A fixed table of ``n_slots`` sequence slots shares one batched decode
cache. Requests queue up; whenever a slot is free the next request is
admitted *mid-flight*: its adapter is materialized through the
:class:`~repro.serve.adapters.AdapterStore`, its prompt is prefilled in
one fused call (``model.prefill`` -- wired for every decode-capable
family, enc-dec included; a per-token fallback remains as a safety net
for models built without one), and the cache rows are scattered into
the slot.
Finished sequences free their slot on the spot -- the engine never
drains the whole batch to admit new work.

Every decode step advances ALL active slots one token, each at its own
position (``decode_step`` takes a per-slot ``pos`` vector). Slots served
by different adapters are handled with one decode dispatch per distinct
active adapter, masked-merged into the shared cache -- compute cost per
step scales with the number of *distinct* adapters in flight, the
classic multi-model batching tradeoff (cf. S-LoRA-style adapter
batching), except here an "adapter" is a replayed scalar log, not extra
weights in the batch.

The engine is family-agnostic: the block-registry runtime's unified
StateCache puts every leaf at (n_layers, B, ...) -- batch on axis 1 for
every family -- so slot scatter/merge is one ``jax.tree.map``, with no
per-family axis table.

MoE caveat: expert capacity is contended across the whole slot batch, so
a slot's logits can depend on what its neighbors decode -- inherent to
capacity-bounded MoE serving, not to this engine.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batching import masked_merge
from repro.models import build_model
from repro.serve import sampling
from repro.serve.adapters import AdapterStore

PyTree = Any


@dataclasses.dataclass
class Request:
    """One generation request, tagged with the adapter that serves it."""
    prompt: np.ndarray            # (P,) int32 token ids
    max_new: int
    user: Optional[str] = None    # adapter id; None -> base weights
    greedy: bool = True
    topk: int = 0                 # used when greedy=False
    temperature: float = 1.0
    rid: int = -1                 # assigned by submit()


@dataclasses.dataclass
class Completion:
    rid: int
    user: Optional[str]
    prompt: np.ndarray
    tokens: np.ndarray            # (n_generated,) int32


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_s: float = 0.0
    decode_tokens: int = 0
    decode_s: float = 0.0
    decode_steps: int = 0
    admitted: int = 0
    finished: int = 0

    @property
    def prefill_tps(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0

    @property
    def decode_tps(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, cfg, store: AdapterStore, n_slots: int = 4,
                 max_len: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"family {cfg.family!r} has no decode path")
        self.store = store
        self.n_slots = n_slots
        self.max_len = max_len or cfg.max_seq
        self.cache = self.model.init_cache(n_slots, self.max_len)
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        self.queue: deque = deque()
        self._next_rid = 0
        self._req: List[Optional[Request]] = [None] * n_slots
        self._active = np.zeros(n_slots, bool)
        self._pos = np.zeros(n_slots, np.int32)
        self._remaining = np.zeros(n_slots, np.int32)
        self._last = np.zeros(n_slots, np.int32)
        self._out: List[List[int]] = [[] for _ in range(n_slots)]
        self._finished: List[Completion] = []

        decode_step = self.model.decode_step

        # the slot-table cache is donated on every hot-path call: decode
        # updates it in place instead of copying the full (n_slots,
        # max_len) KV per token (the reference serve() loop donates too)
        @partial(jax.jit, donate_argnums=(1,))
        def decode_all(params, cache, toks, pos):
            return decode_step(params, cache, toks, pos)

        @partial(jax.jit, donate_argnums=(1,))
        def decode_masked(params, cache, toks, pos, mask):
            logits, new = decode_step(params, cache, toks, pos)
            # every StateCache leaf batches on axis 1 (same ragged-slot
            # helper the TrainEngine uses on its axis-0 user stack)
            return logits, masked_merge(cache, new, mask, axis=1)

        @partial(jax.jit, donate_argnums=(0,))
        def install(cache, prefill_cache, slot):
            """Scatter a B=1 prefilled cache into slot row ``slot``."""

            def put(c, row):
                return c.at[:, slot].set(
                    jnp.take(row, 0, axis=1).astype(c.dtype))

            return jax.tree.map(put, cache, prefill_cache)

        self._decode_all = decode_all
        self._decode_masked = decode_masked
        self._install = install
        self._prefill = (jax.jit(self.model.prefill, donate_argnums=(1,))
                         if self.model.prefill is not None else None)
        self._decode_one = jax.jit(decode_step,   # per-token prefill fallback
                                   donate_argnums=(1,))

    # ---- request lifecycle ----------------------------------------------
    def submit(self, req: Request) -> int:
        plen = int(np.asarray(req.prompt).size)
        if plen + req.max_new > self.max_len:
            raise ValueError(f"prompt({plen}) + max_new({req.max_new}) "
                             f"exceeds max_len({self.max_len})")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        req.rid = self._next_rid
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.n_slots) if not self._active[i]]

    def _admit(self):
        """Prefill queued requests into free slots (mid-flight)."""
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.popleft()
            params = self.store.materialize(req.user)
            prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
            plen = prompt.shape[1]
            t0 = time.perf_counter()
            fresh = self.model.init_cache(1, self.max_len)
            if self._prefill is not None:
                logits, fresh = self._prefill(params, fresh,
                                              jnp.asarray(prompt))
            else:
                toks = jnp.asarray(prompt)
                for t in range(plen):
                    logits, fresh = self._decode_one(params, fresh,
                                                     toks[:, t:t + 1],
                                                     jnp.int32(t))
            self.cache = self._install(self.cache, fresh, slot)
            jax.block_until_ready(self.cache)
            self.stats.prefill_s += time.perf_counter() - t0
            self.stats.prefill_tokens += plen
            self.stats.admitted += 1

            self.key, sub = jax.random.split(self.key)
            tok = self._pick(req, jax.random.fold_in(sub, slot),
                             np.asarray(logits[:, -1, :], np.float32)[0])
            self._req[slot] = req
            self._active[slot] = True
            self._pos[slot] = plen
            self._remaining[slot] = req.max_new - 1
            self._last[slot] = tok
            self._out[slot] = [tok]
            if self._remaining[slot] == 0:
                self._finish(slot)

    def _pick(self, req: Request, key, logits_row: np.ndarray) -> int:
        if req.greedy:
            return int(logits_row.argmax())
        tok = sampling.sample_topk(key[None], jnp.asarray(logits_row)[None],
                                   req.topk or logits_row.size,
                                   req.temperature)
        return int(np.asarray(tok)[0])

    def _finish(self, slot: int):
        req = self._req[slot]
        self._finished.append(Completion(
            rid=req.rid, user=req.user, prompt=np.asarray(req.prompt),
            tokens=np.asarray(self._out[slot], np.int32)))
        self._active[slot] = False
        self._req[slot] = None
        self.stats.finished += 1

    # ---- decode ---------------------------------------------------------
    def step(self):
        """Admit whatever fits, then advance every active slot one token."""
        self._admit()
        if not self._active.any():
            return
        t0 = time.perf_counter()
        toks = jnp.asarray(self._last.reshape(self.n_slots, 1))
        pos = jnp.asarray(np.minimum(self._pos, self.max_len - 1))
        users = {self._req[i].user for i in range(self.n_slots)
                 if self._active[i]}
        merged = np.zeros((self.n_slots, self.cfg.vocab), np.float32)
        if len(users) == 1:
            params = self.store.materialize(next(iter(users)))
            lg, self.cache = self._decode_all(params, self.cache, toks, pos)
            merged[:] = np.asarray(lg[:, -1, :], np.float32)
        else:
            for u in users:
                mask = np.array([self._active[i]
                                 and self._req[i].user == u
                                 for i in range(self.n_slots)])
                params = self.store.materialize(u)
                lg, self.cache = self._decode_masked(
                    params, self.cache, toks, pos, jnp.asarray(mask))
                merged[mask] = np.asarray(lg[:, -1, :], np.float32)[mask]

        self.key, keys = sampling.step_keys(self.key, self.n_slots)
        n_active = int(self._active.sum())
        picked: Dict[int, int] = {}
        groups: Dict[tuple, List[int]] = {}   # (topk, temp) -> slots
        for slot in np.flatnonzero(self._active):
            req = self._req[slot]
            if req.greedy:
                picked[slot] = int(merged[slot].argmax())
            else:
                groups.setdefault((req.topk or self.cfg.vocab,
                                   req.temperature), []).append(int(slot))
        for (k, temp), slots in groups.items():   # one dispatch per combo
            toks_s = sampling.sample_topk(keys[np.asarray(slots)],
                                          jnp.asarray(merged[slots]), k, temp)
            picked.update(zip(slots, np.asarray(toks_s).tolist()))
        for slot, tok in picked.items():
            self._out[slot].append(tok)
            self._last[slot] = tok
            self._pos[slot] += 1
            self._remaining[slot] -= 1
            if (self._remaining[slot] == 0
                    or self._pos[slot] >= self.max_len - 1):
                self._finish(slot)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_tokens += n_active
        self.stats.decode_steps += 1

    def drain_finished(self) -> List[Completion]:
        out, self._finished = self._finished, []
        return out

    def run(self) -> List[Completion]:
        """Serve until queue and slots are empty; completions rid-sorted."""
        out: List[Completion] = []
        while self.queue or self._active.any():
            self.step()
            out.extend(self.drain_finished())
        return sorted(out, key=lambda c: c.rid)
