import gc
import os
import sys

import pytest

# Tests see the default 1-device CPU backend (the dry-run sets its own
# XLA_FLAGS in a separate process -- never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _drop_jax_executables():
    """Free compiled executables between test modules.

    Every XLA:CPU JIT executable pins ~3 anonymous mmap regions
    (rx/ro/rw) for its emitted code; a full-suite run compiles tens of
    thousands of them and the process walks into vm.max_map_count
    (65530 here), where the next compile segfaults inside
    backend_compile instead of raising. Clearing per *module* bounds
    the map count at one module's working set (~5k) while keeping
    cache reuse across a module's parametrized tests.
    """
    yield
    import jax

    jax.clear_caches()
    gc.collect()
