"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep (pip install -e .[test]); tier-1 runs without")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import rng as zrng
from repro.core.mezo import _direction_coeffs
from repro.models.sharding import fit_spec
from repro.models.transformer import softmax_xent
from repro.optim.compression import int8_dequantize, int8_quantize
from jax.sharding import Mesh, PartitionSpec as P

SETTINGS = dict(max_examples=25, deadline=None)


@given(seed=st.integers(0, 2**32 - 1), salt=st.integers(0, 2**32 - 1),
       rows=st.integers(1, 40), cols=st.integers(1, 40),
       r0=st.integers(0, 1000), c0=st.integers(0, 1000))
@settings(**SETTINGS)
def test_rng_tile_equals_slice(seed, salt, rows, cols, r0, c0):
    """Any tile with offsets == the same slice of a bigger field."""
    full = zrng.z_field(jnp.uint32(seed), salt, (r0 + rows, c0 + cols))
    tile = zrng.z_field(jnp.uint32(seed), salt, (rows, cols),
                        offsets=(r0, c0))
    np.testing.assert_array_equal(np.asarray(full[r0:, c0:]),
                                  np.asarray(tile))


@given(k=st.integers(1, 16), lr=st.floats(1e-6, 1.0),
       data=st.data())
@settings(**SETTINGS)
def test_direction_coeffs_sum_preserved(k, lr, data):
    """Masked renormalization keeps |sum coeffs| == lr (unbiased scale)."""
    mask = np.array(data.draw(st.lists(st.sampled_from([0.0, 1.0]),
                                       min_size=k, max_size=k)), np.float32)
    coeffs = np.asarray(_direction_coeffs(k, jnp.float32(lr), mask))
    if mask.sum() == 0:
        return
    np.testing.assert_allclose(-coeffs.sum(), lr, rtol=1e-5)
    assert (coeffs[mask == 0] == 0).all()


@given(b=st.integers(1, 4), s=st.integers(1, 8), v=st.integers(2, 30),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_softmax_xent_matches_numpy(b, s, v, seed):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((b, s, v)).astype(np.float32) * 3
    targets = rng.integers(0, v, (b, s))
    got = float(softmax_xent(jnp.asarray(logits), jnp.asarray(targets)))
    ex = np.exp(logits - logits.max(-1, keepdims=True))
    p = ex / ex.sum(-1, keepdims=True)
    want = -np.log(np.take_along_axis(p, targets[..., None], -1)).mean()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
@settings(**SETTINGS)
def test_int8_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)).astype(np.float32) * scale)
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s)
    # error bounded by one quantization bucket
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) + 1e-6


@given(dim=st.integers(1, 64), nd=st.integers(1, 3),
       data=st.data())
@settings(max_examples=25, deadline=None)
def test_fit_spec_always_divides(dim, nd, data):
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    axes = data.draw(st.lists(st.sampled_from([None, "data", "model"]),
                              min_size=nd, max_size=nd, unique_by=id))
    shape = tuple(data.draw(st.integers(1, 64)) for _ in range(nd))
    spec = fit_spec(shape, P(*axes), mesh)
    sizes = {"data": 4, "model": 4}
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        prod = int(np.prod([sizes[n] for n in names]))
        assert shape[d] % prod == 0


# ---- multi-tenant seed isolation (train.engine) ---------------------------

from repro.train.engine import derive_user_seed  # noqa: E402

_name = st.text(st.characters(min_codepoint=33, max_codepoint=126),
                min_size=1, max_size=12)


@given(engine_seed=st.integers(0, 2**32 - 1), data=st.data())
@settings(**SETTINGS)
def test_user_leaf_z_streams_pairwise_distinct(engine_seed, data):
    """No two (user, leaf) pairs in a batch draw the same z stream:
    per-user base seeds fold per-leaf salts through the avalanche hash,
    so every (user, leaf) gets its own counter stream."""
    from hypothesis import assume
    users = data.draw(st.lists(_name, min_size=2, max_size=4, unique=True))
    leaves = data.draw(st.lists(_name, min_size=2, max_size=3, unique=True))
    assume(len({zrng.leaf_salt(u) for u in users}) == len(users))
    assume(len({zrng.leaf_salt(p) for p in leaves}) == len(leaves))
    streams = {}
    for u in users:
        us = jnp.uint32(derive_user_seed(engine_seed, u))
        for path in leaves:
            z = np.asarray(zrng.z_field(
                zrng.fold_seed(us, 0), zrng.leaf_salt(path), (2, 32)))
            streams[(u, path)] = z.tobytes()
    assert len(set(streams.values())) == len(streams), \
        "two (user, leaf) pairs drew identical z streams"


@given(engine_seed=st.integers(0, 2**32 - 1), data=st.data())
@settings(**SETTINGS)
def test_derive_user_seed_injective_over_users(engine_seed, data):
    """Distinct users (distinct crc32 salts) get distinct base seeds."""
    from hypothesis import assume
    users = data.draw(st.lists(_name, min_size=2, max_size=8, unique=True))
    assume(len({zrng.leaf_salt(u) for u in users}) == len(users))
    seeds = {derive_user_seed(engine_seed, u) for u in users}
    assert len(seeds) == len(users)


@given(engine_seed=st.integers(0, 2**32 - 1),
       step=st.integers(0, 10_000), data=st.data())
@settings(**SETTINGS)
def test_per_step_seed_independent_of_slot_order(engine_seed, step, data):
    """Slot reassignment never reuses a stale seed: the per-step seed is
    a pure function of (engine_seed, user, step), so any permutation of
    users across the slot table computes the same per-user seeds."""
    users = data.draw(st.lists(_name, min_size=2, max_size=4, unique=True))
    perm = data.draw(st.permutations(users))
    base = {u: np.uint32(derive_user_seed(engine_seed, u)) for u in users}
    direct = {u: int(np.asarray(zrng.fold_seed(
        jnp.uint32(base[u]), jnp.uint32(step)))) for u in users}
    # recompute through a permuted "slot table" (vectorized, as step() does)
    tbl = np.asarray([base[u] for u in perm], np.uint32)
    folded = np.asarray(zrng.fold_seed(
        tbl, np.full(len(perm), step, np.uint32)), np.uint32)
    for slot, u in enumerate(perm):
        assert int(folded[slot]) == direct[u]


@given(seed=st.integers(0, 2**32 - 1), n_slots=st.integers(1, 6),
       k=st.integers(1, 16), temp=st.floats(0.1, 3.0),
       steps=st.integers(1, 4))
@settings(**SETTINGS)
def test_seeded_sampling_reproducible_across_step_keys(seed, n_slots, k,
                                                       temp, steps):
    """The engine's sampling chain -- one key split per step, fold_in per
    slot, top-k draw -- is a pure function of (seed, step, slot): replays
    reproduce bit-identically, and per-slot streams stay distinct."""
    from repro.serve import sampling

    rng = np.random.default_rng(seed % 2**16)
    logits = jnp.asarray(rng.normal(size=(n_slots, 32)).astype(np.float32))

    def chain():
        key = jax.random.PRNGKey(seed)
        toks = []
        for _ in range(steps):
            key, ks = sampling.step_keys(key, n_slots)
            toks.append(np.asarray(
                sampling.sample_topk(ks, logits, k, temp)))
        return np.stack(toks), np.asarray(key)

    t1, k1 = chain()
    t2, k2 = chain()
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(k1, k2)
    assert t1.shape == (steps, n_slots)
    assert np.all((t1 >= 0) & (t1 < 32))
