"""Multi-tenant fine-tune launcher: N users through one TrainEngine.

The serving launcher answers "how many users can one device *hold*";
this one answers "how many users can one device *train at once*". A
fleet of per-user fine-tune jobs shares a single resident base (f32 or
int8-quantized) and a batched TrainEngine advances every resident job
per dispatch -- each user's trajectory bit-identical to a lone
sequential Trainer run with that user's derived seed.

  PYTHONPATH=src python -m repro.launch.train_fleet --arch gemma-2b \
      --reduced --users 8 --slots 4 --steps 20 --quant int8
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import zlib

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core.engine import estimator_names, update_rule_names
from repro.core.mezo import MezoConfig
from repro.runtime.trainer import train_multi_tenant
from repro.train import TrainJob


def user_batches(cfg, user: str, batch: int, seq: int, seed: int):
    """Deterministic per-(user, step) LM batches: a resumed job replays
    exactly the batches the uninterrupted run would have consumed."""
    salt = zlib.crc32(f"{seed}/{user}".encode()) & 0x7FFFFFFF

    def fn(step: int):
        rng = np.random.default_rng((salt, step))
        toks = rng.integers(0, cfg.vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "loss_mask": np.ones((batch, seq), np.float32)}
    return fn


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--users", type=int, default=8,
                    help="fine-tune jobs to run (user-0 .. user-N-1)")
    ap.add_argument("--slots", type=int, default=4,
                    help="resident jobs per batched dispatch")
    ap.add_argument("--steps", type=int, default=20,
                    help="ZO steps per user")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--estimator", default="fused",
                    choices=[e for e in estimator_names() if e != "walk"],
                    help="pristine direction evaluator (the in-place walk "
                         "cannot give replay-log bit-parity)")
    ap.add_argument("--update", default="sgd", choices=update_rule_names())
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--directions", type=int, default=1)
    ap.add_argument("--zo-dist", default="rademacher",
                    choices=["rademacher", "gaussian"])
    ap.add_argument("--quant", default="none",
                    help="base-weight quantization (none | int8): int8 "
                         "keeps ONE ~1 byte/param base resident for every "
                         "user; per-user state is only the f32 deltas")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route aligned projections through the Pallas ZO "
                         "kernels (slow interpret mode off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-dir", default=None,
                    help="append per-user replay logs under this dir "
                         "(crash recovery: AdapterStore.load per user)")
    ap.add_argument("--out", default=None, help="summary JSON path")
    return ap


def main():
    args = build_argparser().parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.seq and cfg.family != "encoder":
        cfg = dataclasses.replace(cfg, max_seq=max(cfg.max_seq, args.seq))

    mz = MezoConfig(eps=args.eps, lr=args.lr, n_directions=args.directions,
                    dist=args.zo_dist, use_kernel=args.use_kernel)
    jobs = [TrainJob(user=f"user-{i}",
                     batches=user_batches(cfg, f"user-{i}", args.batch,
                                          args.seq, args.seed),
                     n_steps=args.steps)
            for i in range(args.users)]
    engine, results = train_multi_tenant(
        cfg, jobs, n_slots=args.slots, estimator=args.estimator,
        update=args.update, seed=args.seed, mezo_cfg=mz, quant=args.quant,
        log_dir=args.log_dir)

    for r in results:
        print(f"[fleet] {r.user}: steps {r.start_step}->{r.n_steps} "
              f"loss {r.losses[0]:.4f} -> {r.losses[-1]:.4f}")
    s = engine.stats
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "users": args.users,
                       "slots": args.slots, "steps": args.steps,
                       "quant": args.quant,
                       "user_steps_per_s": s.user_steps_per_s,
                       "dispatches": s.dispatches,
                       "losses": {r.user: r.losses for r in results}}, f)
    print(f"[fleet] {s.finished} users x {args.steps} steps in "
          f"{s.dispatches} dispatches: {s.user_steps_per_s:.2f} "
          f"user-steps/s")


if __name__ == "__main__":
    main()
