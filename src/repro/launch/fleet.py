"""Async direction-service launcher: elastic fleet ZO training.

``train_fleet`` batches N users through one synchronous engine; this
launcher runs ONE training job across an elastic fleet of heterogeneous
workers -- a coordinator hands out (step, seed, K) direction leases,
workers return projected gradients at their own modeled pace, and the
coordinator applies them staleness-decayed, logging every applied update
so the run replays bit-exactly from theta_0 (``--verify-replay`` checks
exactly that, atol=0, after injected stragglers / duplicate deliveries /
mid-run join+leave).

  PYTHONPATH=src python -m repro.launch.fleet --arch gemma-2b --reduced \
      --workers 4 --stragglers 1 --steps 24 --join-after 6 \
      --leave-after 12 --log runs/fleet.jsonl --verify-replay
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import ALL_ARCHS, get_config
from repro.core.engine import MezoConfig, estimator_names
from repro.runtime.fleet import (DEVICE_GRADES, FaultSpec, FleetSim,
                                 WorkerSpec)


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--workers", type=int, default=4,
                    help="initial fleet size")
    ap.add_argument("--grade", default="flagship",
                    choices=sorted(DEVICE_GRADES),
                    help="device grade of the fleet (roofline latency "
                         "profile)")
    ap.add_argument("--stragglers", type=int, default=0,
                    help="how many workers run --straggler-scale slower")
    ap.add_argument("--straggler-scale", type=float, default=5.0)
    ap.add_argument("--duplicate-every", type=int, default=0,
                    help="worker 0 delivers every Nth result twice "
                         "(transport-retry fault injection)")
    ap.add_argument("--steps", type=int, default=24,
                    help="updates to apply before stopping")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--estimator", default="fused",
                    choices=[e for e in estimator_names() if e != "walk"],
                    help="pristine direction evaluator (leased params "
                         "snapshots are shared by reference; the in-place "
                         "walk would corrupt them)")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--directions", type=int, default=2)
    ap.add_argument("--zo-dist", default="rademacher",
                    choices=["rademacher", "gaussian"])
    ap.add_argument("--staleness-decay", type=float, default=0.95,
                    help="applied update scaled by decay**staleness "
                         "(updates applied since the worker's params "
                         "snapshot); 1.0 = no decay")
    ap.add_argument("--deadline-factor", type=float, default=3.0,
                    help="lease expiry budget: factor x EMA-median "
                         "latency (StragglerPolicy)")
    ap.add_argument("--join-after", type=int, default=None,
                    help="admit one extra worker after this many applied "
                         "updates (elastic resize mid-round)")
    ap.add_argument("--leave-after", type=int, default=None,
                    help="retire the last initial worker after this many "
                         "applied updates")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default=None,
                    help="replay-log path (staleness-bearing JSONL)")
    ap.add_argument("--out", default=None, help="summary JSON path")
    ap.add_argument("--verify-replay", action="store_true",
                    help="replay the log from theta_0 and require "
                         "bit-exact (atol=0) agreement with live params")
    return ap


def main():
    args = build_argparser().parse_args()
    if args.stragglers > args.workers:
        raise SystemExit(f"--stragglers {args.stragglers} exceeds "
                         f"--workers {args.workers}")
    for flag, val in (("--join-after", args.join_after),
                      ("--leave-after", args.leave_after)):
        if val is not None and not 0 < val < args.steps:
            raise SystemExit(f"{flag} {val} must lie inside (0, --steps "
                             f"{args.steps}) to fire mid-round")
    if args.verify_replay and not args.log:
        raise SystemExit("--verify-replay needs --log (the replay source)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mz = MezoConfig(eps=args.eps, lr=args.lr,
                    n_directions=args.directions, dist=args.zo_dist,
                    staleness_decay=args.staleness_decay)

    workers = []
    for i in range(args.workers):
        faults = FaultSpec(jitter=0.2)
        if i >= args.workers - args.stragglers:
            faults.latency_scale = args.straggler_scale
        if i == 0 and args.duplicate_every:
            faults.duplicate_every = args.duplicate_every
        workers.append(WorkerSpec(args.grade, faults))

    step_events = []
    if args.join_after is not None:
        step_events.append((args.join_after, "join",
                            WorkerSpec(args.grade, FaultSpec(jitter=0.2))))
    if args.leave_after is not None:
        step_events.append((args.leave_after, "leave", args.workers - 1))

    sim = FleetSim(cfg, workers, total_steps=args.steps, mezo_cfg=mz,
                   batch=args.batch, seq=args.seq, seed=args.seed,
                   estimator=args.estimator,
                   deadline_factor=args.deadline_factor,
                   log_path=args.log, step_events=step_events)
    rep = sim.run()

    print(f"[fleet] {rep.applied} updates applied over "
          f"{rep.virtual_s * 1e3:.2f} virtual ms "
          f"({rep.virtual_steps_per_s:.1f} steps/s modeled); "
          f"reissued {rep.reissued}, dropped {rep.dropped} late/dup "
          f"deliveries, {rep.resizes} elastic resizes, "
          f"max staleness {max(rep.staleness)}")
    print(f"[fleet] loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}")

    replay_ok = None
    if args.verify_replay:
        import jax
        import jax.numpy as jnp

        from repro.checkpoint.replay_log import ReplayLog, replay_into
        recs = ReplayLog.read(args.log)
        p0 = sim.model.init(jax.random.PRNGKey(args.seed))
        replayed, _ = replay_into(p0, recs, mz)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)
            ))), replayed, rep.params)))
        replay_ok = diff == 0.0
        print(f"[fleet] replay-from-log max |diff| = {diff} "
              f"({'bit-exact' if replay_ok else 'MISMATCH'})")
        if not replay_ok:
            raise SystemExit(1)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "workers": args.workers,
                       "stragglers": args.stragglers, "steps": args.steps,
                       "applied": rep.applied, "reissued": rep.reissued,
                       "dropped": rep.dropped, "resizes": rep.resizes,
                       "virtual_s": rep.virtual_s,
                       "virtual_steps_per_s": rep.virtual_steps_per_s,
                       "max_staleness": max(rep.staleness),
                       "losses": rep.losses,
                       "replay_bitexact": replay_ok}, f)


if __name__ == "__main__":
    main()
