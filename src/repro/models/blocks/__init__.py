"""Block registry: every mixer/FFN behind one protocol (see base.py).

Importing this package registers the built-in block types; family
assembly (`repro.models.transformer`) and the generic backbone engine
(`repro.models.runtime`) resolve them by name.
"""

from repro.models.blocks.base import (BlockType, RunCtx, block_names,
                                      get_block, register_block)
from repro.models.blocks import attention as _attention          # noqa: F401
from repro.models.blocks import cross_attention as _cross        # noqa: F401
from repro.models.blocks import ffn as _ffn                      # noqa: F401
from repro.models.blocks import mamba as _mamba                  # noqa: F401
from repro.models.blocks import rwkv as _rwkv                    # noqa: F401

__all__ = ["BlockType", "RunCtx", "block_names", "get_block",
           "register_block"]
