"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

No device allocation: params/batch/cache are shape-only stand-ins with
NamedShardings attached, feeding ``jax.jit(...).lower(...)`` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build_model, sharding as shd
from repro.models.config import ModelConfig

# The assigned input-shape set (LM family: seq_len x global_batch).
SHAPES: Dict[str, dict] = {
    "train_4k":    dict(mode="train",   seq=4096,   batch=256),
    "prefill_32k": dict(mode="prefill", seq=32768,  batch=32),
    "decode_32k":  dict(mode="decode",  seq=32768,  batch=128),
    "long_500k":   dict(mode="decode",  seq=524288, batch=1),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if supported, else a skip reason (recorded in EXPERIMENTS.md)."""
    if shape_name == "long_500k" and not cfg.is_sub_quadratic:
        return ("pure full-attention arch: 524k decode requires "
                "sub-quadratic attention (skip per assignment)")
    if SHAPES[shape_name]["mode"] == "decode" and cfg.family == "encoder":
        return "encoder-only arch has no decode step"
    return None


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _data_axes(mesh, cfg=None):
    axes = ["pod", "data"]
    if cfg is not None and not cfg.use_tp:
        axes.append("model")     # no TP: the model axis joins DP
    return [a for a in axes if a in mesh.axis_names]


def _with_shardings(tree, spec_tree_, mesh):
    spec_tree_ = shd.fit_specs(tree, spec_tree_, mesh)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, spec_tree_)


def param_specs(model, mesh):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.spec_tree(shapes, fsdp=model.cfg.fsdp_params,
                          use_tp=model.cfg.use_tp)
    return _with_shardings(shapes, specs, mesh)


def batch_struct(cfg: ModelConfig, seq: int, batch: int) -> Dict[str, Any]:
    """abstract train/prefill batch for this architecture."""
    b: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32),
    }
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.d_model), dt)
    if cfg.num_patches:
        b["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), dt)
    return b


def cell_inputs(cfg: ModelConfig, shape_name: str, mesh):
    """Returns dict(mode, fn_args=(...), metadata) for the cell, where
    fn_args are fully-sharded ShapeDtypeStructs in the order the lowered
    step function expects them."""
    reason = cell_supported(cfg, shape_name)
    if reason:
        raise ValueError(f"unsupported cell: {reason}")
    sh = SHAPES[shape_name]
    model = build_model(cfg)
    mesh_params = param_specs(model, mesh)
    daxes = _data_axes(mesh, cfg)

    if sh["mode"] in ("train", "prefill"):
        batch = batch_struct(cfg, sh["seq"], sh["batch"])
        bspecs = shd.batch_spec(batch, mesh, data_axes=daxes)
        batch = _with_shardings(batch, bspecs, mesh)
        return dict(mode=sh["mode"], model=model, params=mesh_params,
                    batch=batch,
                    seed=jax.ShapeDtypeStruct((), jnp.uint32))

    # decode
    cache_shapes = jax.eval_shape(lambda: model.init_cache(sh["batch"],
                                                           sh["seq"]))
    cspecs = shd.cache_spec(cache_shapes, mesh)
    cache = _with_shardings(cache_shapes, cspecs, mesh)
    tok_spec = shd._fit(mesh, sh["batch"], *daxes)
    tokens = _sds((sh["batch"], 1), jnp.int32, mesh, P(tok_spec, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return dict(mode="decode", model=model, params=mesh_params,
                cache=cache, tokens=tokens, pos=pos)
