"""jamba-v0.1-52b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
        act="swiglu", norm="rmsnorm", pos="none",   # jamba uses no pos emb
        n_experts=16, topk=2, expert_dff=14336, capacity_factor=1.25, moe_ep=True,
        block_len=8, attn_index=4, mamba_d_state=16, mamba_d_conv=4,
        mamba_expand=2, max_seq=524288)
