"""int8 gradient compression for the derivative-based (Adam) baseline.

A distributed-optimization trick for the *gradient* arm only: MeZO's
cross-pod traffic is already K scalars per step, so compression there is
moot -- which is precisely the paper's systems advantage at scale.

Per-leaf symmetric int8 quantization with an fp32 absmax scale. Under jit
SPMD the subsequent psum runs over int32-accumulated values; stochastic
rounding keeps the compressed estimator unbiased.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng as zrng


def int8_quantize(g: jnp.ndarray, seed=jnp.uint32(0x51CA)):
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-30
    x = g.astype(jnp.float32) / scale
    # stochastic rounding via the same hash field used for ZO noise
    u = (zrng._coord_hash(seed, 0xC0DE, g.shape) >> 8).astype(jnp.float32) \
        * (1.0 / 16777216.0)
    q = jnp.clip(jnp.floor(x + u), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_compress_tree(grads):
    """Quantize->dequantize each float leaf (simulates on-the-wire int8).

    Under pjit the psum over the data axis happens on the dequantized
    value; the roundtrip here is what bounds the numerical error, while
    the wire format in a manual shard_map pipeline would ship (q, scale).
    """
    def roundtrip(g):
        if not jnp.issubdtype(g.dtype, jnp.floating) or g.ndim == 0:
            return g
        q, s = int8_quantize(g)
        return int8_dequantize(q, s, g.dtype)
    return jax.tree.map(roundtrip, grads)
