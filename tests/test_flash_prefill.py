"""Chunked-prefill kernel: parity against the gather reference and a
dense attention oracle, across GQA layouts, chunk-start positions that
straddle page boundaries, and scrambled page tables -- plus the C=1
degeneration to flash_decode's reference math.

The Pallas kernel runs in interpret mode here (CI is CPU); the serving
hot path routes through :func:`prefill_attn_ref` off-TPU, so both
implementations are pinned against the same dense oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import MXU_HEAD_DIMS, paged_attn_ref
from repro.kernels.flash_prefill import flash_prefill, prefill_attn_ref
from repro.models.layers import attention

PS = 8  # page size


def _prefill_case(seed, b, c, h, kvh, hd, n_live, pos):
    """Random chunk queries + page pools with a *scrambled* page table:
    each slot's logical pages map to arbitrary distinct physical pages
    (page 0 kept as the trash page). The table covers the whole chunk
    (pos + c - 1), as the admission's up-front prompt-page allocation
    guarantees."""
    rng = np.random.default_rng(seed)
    n_pages = 1 + b * n_live + 3          # trash + slots' pages + spares
    q = rng.normal(size=(b, c, h, hd)).astype(np.float32)
    k = rng.normal(size=(n_pages, PS, kvh, hd)).astype(np.float32)
    v = rng.normal(size=(n_pages, PS, kvh, hd)).astype(np.float32)
    pos = np.asarray(pos, np.int32)
    perm = rng.permutation(np.arange(1, n_pages))   # never hand out trash
    pages = np.zeros((b, n_live), np.int32)
    for i in range(b):
        live = 1 + (pos[i] + c - 1) // PS
        pages[i, :live] = perm[i * n_live:i * n_live + live]
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(pages), jnp.asarray(pos))


def _dense_oracle(q, k_pages, v_pages, pages, pos):
    """Gather pages to contiguous (B, S, KV, hd) and run plain masked
    attention with the per-offset causal limit -- the layout-free
    ground truth."""
    b, c, h, hd = q.shape
    kk = np.asarray(k_pages)[np.asarray(pages)].reshape(
        b, -1, *k_pages.shape[2:])
    vv = np.asarray(v_pages)[np.asarray(pages)].reshape(
        b, -1, *v_pages.shape[2:])
    qpos = np.asarray(pos)[:, None] + np.arange(c)[None, :]
    valid = np.arange(kk.shape[1])[None, None, :] <= qpos[:, :, None]
    out = attention(q, jnp.asarray(kk), jnp.asarray(vv),
                    causal=False, kv_mask=jnp.asarray(valid), chunk=0)
    return np.asarray(out)


# chunk starts that straddle page boundaries from every side: a fresh
# prompt (pos 0, the first chunk), a chunk starting on the last row of a
# page, on a fresh page, and mid-page -- and C > PS below makes single
# chunks span multiple pages outright
RAGGED_POS = (PS - 2, PS, 2 * PS + 3, 0)


@pytest.mark.parametrize("kvh,g", [(1, 4), (2, 2), (4, 1)])
def test_kernel_matches_dense_oracle_gqa(kvh, g):
    q, k, v, pages, pos = _prefill_case(0, b=4, c=4, h=kvh * g, kvh=kvh,
                                        hd=16, n_live=4, pos=RAGGED_POS)
    want = _dense_oracle(q, k, v, pages, pos)
    got_ref = np.asarray(prefill_attn_ref(q, k, v, pages, pos))
    got_kern = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(got_ref, want, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(got_kern, want, rtol=2e-4, atol=2e-5)


def test_chunk_wider_than_page():
    """C > page_size: one chunk's rows span several pages, so a single
    page sweep step serves rows before, inside, and after its span."""
    q, k, v, pages, pos = _prefill_case(6, b=3, c=2 * PS + 3, h=4, kvh=2,
                                        hd=16, n_live=6, pos=(0, PS - 1, 5))
    want = _dense_oracle(q, k, v, pages, pos)
    got = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    ref = np.asarray(prefill_attn_ref(q, k, v, pages, pos))
    np.testing.assert_allclose(ref, want, rtol=2e-4, atol=2e-5)


def test_causal_inside_chunk():
    """Chunk offset c must see positions [0, pos + c] and nothing later:
    poisoning the K/V at chunk offset j must change offsets >= j only."""
    c = 4
    q, k, v, pages, pos = _prefill_case(1, b=2, c=c, h=2, kvh=1, hd=16,
                                        n_live=3, pos=(3, PS - 1))
    base = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    j = 2                                 # poison chunk offset j's K/V
    pg = np.asarray(pages)
    pp = np.asarray(pos)
    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    for b_ in range(2):
        p_ = pp[b_] + j
        k2[pg[b_, p_ // PS], p_ % PS] = 1e3
        v2[pg[b_, p_ // PS], p_ % PS] = 1e3
    got = np.asarray(flash_prefill(q, jnp.asarray(k2), jnp.asarray(v2),
                                   pages, pos, interpret=True))
    np.testing.assert_allclose(got[:, :j], base[:, :j], rtol=1e-6)
    assert not np.allclose(got[:, j:], base[:, j:])
    ref = np.asarray(prefill_attn_ref(q, jnp.asarray(k2), jnp.asarray(v2),
                                      pages, pos))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_kernel_ignores_trash_page_contents():
    """Dead table entries point at physical page 0; whatever is in it
    must not leak into any slot's chunk."""
    q, k, v, pages, pos = _prefill_case(2, b=3, c=3, h=4, kvh=2, hd=16,
                                        n_live=4, pos=(3, PS, 2 * PS - 2))
    poisoned_k = k.at[0].set(1e4)
    poisoned_v = v.at[0].set(1e4)
    a = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    bb = np.asarray(flash_prefill(q, poisoned_k, poisoned_v, pages, pos,
                                  interpret=True))
    np.testing.assert_allclose(a, bb, rtol=1e-6)
    r = np.asarray(prefill_attn_ref(q, poisoned_k, poisoned_v, pages, pos))
    np.testing.assert_allclose(a, r, rtol=2e-4, atol=2e-5)


def test_c1_degenerates_to_flash_decode_reference():
    """A one-token chunk is exactly paged decode attention: the ref
    must match paged_attn_ref bitwise on the same inputs."""
    q, k, v, pages, pos = _prefill_case(3, b=3, c=1, h=4, kvh=2, hd=16,
                                        n_live=4, pos=(PS - 1, PS, 5))
    ours = np.asarray(prefill_attn_ref(q, k, v, pages, pos))
    theirs = np.asarray(paged_attn_ref(q[:, 0], k, v, pages, pos))
    np.testing.assert_array_equal(ours[:, 0], theirs)
    kern = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(kern[:, 0], theirs, rtol=2e-4, atol=2e-5)


def test_single_live_page():
    """n_live == 1: the init / accumulate / finalize grid steps coincide
    and the whole chunk lives in one page."""
    q, k, v, pages, pos = _prefill_case(4, b=2, c=3, h=2, kvh=1, hd=16,
                                        n_live=1, pos=(0, 2))
    want = _dense_oracle(q, k, v, pages, pos)
    got = np.asarray(flash_prefill(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_head_dim_validation():
    """Off-MXU head dims must be a loud ValueError when compiling for
    real hardware (interpret mode lifts it for CI correctness runs)."""
    q, k, v, pages, pos = _prefill_case(5, b=2, c=2, h=2, kvh=1, hd=16,
                                        n_live=2, pos=(1, 2))
    with pytest.raises(ValueError, match="MXU"):
        flash_prefill(q, k, v, pages, pos, interpret=False)
    for hd in MXU_HEAD_DIMS:  # aligned dims pass validation (trace only)
        jax.eval_shape(
            lambda qq, kk, vv: flash_prefill(qq, kk, vv, pages, pos,
                                             interpret=True),
            jax.ShapeDtypeStruct((2, 2, 2, hd), jnp.float32),
            jax.ShapeDtypeStruct(k.shape[:3] + (hd,), jnp.float32),
            jax.ShapeDtypeStruct(v.shape[:3] + (hd,), jnp.float32))
