"""End-to-end personalized-LLM flow (the paper's motivating scenario):

  1. fine-tune a (reduced) LM on "private on-device data" with MeZO,
  2. checkpoint (snapshot + replay log),
  3. reload in a fresh manager and serve batched requests.

  PYTHONPATH=src python examples/serve_personalized.py
"""

import os
import shutil
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batches
from repro.launch.serve import serve
from repro.runtime import Trainer, TrainerConfig


def main():
    cfg = get_config("gemma-2b").reduced()
    ckpt = "/tmp/pocketllm_personalized"
    shutil.rmtree(ckpt, ignore_errors=True)

    mz = MezoConfig(eps=1e-2, lr=5e-3, n_directions=4)
    tc = TrainerConfig(optimizer="mezo", mezo=mz, n_steps=40,
                       ckpt_dir=ckpt, snapshot_every=20, log_every=10)
    tr = Trainer(cfg, tc, lm_batches(8, 32, cfg.vocab, seed=11))
    tr.train()
    print(f"fine-tuned: loss {tr.losses[0]:.3f} -> {tr.losses[-1]:.3f}")

    # fresh "serving process": restore snapshot + replay tail
    like = Trainer(cfg, tc, iter(())).init_params()
    params, nxt = CheckpointManager(ckpt, mezo_cfg=mz,
                                    snapshot_every=20).restore(like)
    print(f"restored at step {nxt} (snapshot + replay log)")

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 8), dtype=np.int32)
    toks = serve(cfg, params, prompts, gen=6)
    print("generated:", toks)
    assert toks.shape == (4, 6)
    print("OK: fine-tune -> checkpoint -> restore -> serve")


if __name__ == "__main__":
    main()
