"""Serving subsystem: fused-prefill/decode parity, adapter store
semantics, and continuous-batching engine behavior."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import MezoConfig, mezo_step_vmapdir
from repro.launch.serve import serve
from repro.models import build_model
from repro.serve import AdapterStore, Request, ServeEngine, tree_bytes


def _synthetic_records(n, k=2, seed=0, lr=5e-2, eps=1e-2):
    rng = np.random.default_rng(seed)
    return [{"step": i, "seed": int(rng.integers(2**31)),
             "gs": rng.normal(size=k).astype(np.float32).tolist(),
             "lr": lr, "eps": eps} for i in range(n)]


# ---------------------------------------------------------------------------
# prefill / decode parity (satellite: transformer + one non-transformer)


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-7b", "whisper-base"])
def test_engine_matches_per_token_loop(arch):
    """Fused prefill + batched decode must emit the same greedy tokens as
    the reference per-token loop (the old serve()). whisper-base pins the
    enc-dec prefill the runtime refactor added (cross K/V read from the
    StateCache, zeros for token-only serving -- same as the loop)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, G = 2, 9, 6
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (B, P),
                                            0, cfg.vocab), np.int32)
    ref = serve(cfg, params, prompts, gen=G)

    engine = ServeEngine(cfg, AdapterStore(params), n_slots=B,
                         max_len=P + G, seed=0)
    rids = [engine.submit(Request(prompt=prompts[i], max_new=G))
            for i in range(B)]
    outs = {c.rid: c.tokens for c in engine.run()}
    got = np.stack([outs[r] for r in rids])
    np.testing.assert_array_equal(got, ref)


def test_engine_staggered_lengths_match_individual_serves():
    """Continuous batching with per-slot positions: requests of different
    prompt lengths, admitted mid-flight through 2 slots, must each decode
    exactly what a dedicated single-request loop would."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    G = 5
    plens = [5, 9, 7]
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (p,), 0, cfg.vocab), np.int32)
               for i, p in enumerate(plens)]
    refs = [serve(cfg, params, pr[None], gen=G)[0] for pr in prompts]

    engine = ServeEngine(cfg, AdapterStore(params), n_slots=2,
                         max_len=max(plens) + G, seed=0)
    rids = [engine.submit(Request(prompt=pr, max_new=G)) for pr in prompts]
    outs = {c.rid: c.tokens for c in engine.run()}
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)


def test_hybrid_prefill_matches_decode_loop():
    """Direct model-layer parity for the mamba-hybrid family: fused
    prefill logits and cache == P decode_step calls."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    # capacity semantics differ between T=B*S and T=B token batches; use
    # generous capacity so routing drops nothing either way (the same
    # caveat as test_decode_matches_forward)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, P = 2, 7
    toks = jnp.asarray(np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (B, P), 0, cfg.vocab),
        np.int32))
    cache = model.init_cache(B, P + 4)
    lg = None
    for t in range(P):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
    pf_lg, pf_cache = model.prefill(params, model.init_cache(B, P + 4), toks)
    np.testing.assert_allclose(np.asarray(pf_lg, np.float32),
                               np.asarray(lg, np.float32),
                               rtol=2e-3, atol=2e-3)
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(cache),
            jax.tree_util.tree_leaves_with_path(pf_cache)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=jax.tree_util.keystr(ka))


def test_decode_step_vector_pos_matches_scalar():
    cfg = get_config("qwen3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 3
    tok = jnp.zeros((B, 1), jnp.int32)
    cs, cv = model.init_cache(B, 8), model.init_cache(B, 8)
    for t in range(3):
        lg_s, cs = model.decode_step(params, cs, tok, jnp.int32(t))
        lg_v, cv = model.decode_step(params, cv, tok,
                                     jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s, np.float32),
                               np.asarray(lg_v, np.float32),
                               rtol=1e-5, atol=1e-6)
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(cs),
            jax.tree_util.tree_leaves_with_path(cv)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=jax.tree_util.keystr(ka))


# ---------------------------------------------------------------------------
# adapter store


def _tiny_params(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": {"w": jax.random.normal(k, (8, 16))},
            "b": jnp.arange(5, dtype=jnp.float32)}


def test_adapter_materialize_matches_checkpoint_restore(tmp_path):
    """AdapterStore.materialize (full-log replay from base) must be
    bit-identical to CheckpointManager.restore (snapshot + tail replay)
    for the pristine-base-point estimator."""
    params = _tiny_params(1)

    def loss_fn(p, _):
        return jnp.sum(p["a"]["w"] ** 2) * 1e-3 + jnp.sum(p["b"] ** 2) * 1e-3

    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=4)
    p = jax.tree.map(jnp.copy, params)
    for step in range(9):
        p, aux = mezo_step_vmapdir(loss_fn, p, None, jnp.uint32(step), cfg)
        mgr.on_step(step, p, aux)

    restored, nxt = CheckpointManager(str(tmp_path), mezo_cfg=cfg,
                                      snapshot_every=4).restore(params)
    assert nxt == 9
    store = AdapterStore(params, cfg)
    store.import_checkpoint("u", str(tmp_path))
    mat = store.materialize("u")
    for a, b, live in zip(jax.tree.leaves(mat), jax.tree.leaves(restored),
                          jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(live))


def test_adapter_momentum_rule_replay_matches_live():
    """A momentum-trained run's adapter must materialize through the
    same update rule: full-log replay from a fresh history window equals
    the live trajectory bit-for-bit."""
    from repro.core import build_strategy
    params = _tiny_params(2)

    def loss_fn(p, _):
        return jnp.sum(p["a"]["w"] ** 2) * 1e-3 + jnp.sum(p["b"] ** 2) * 1e-3

    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, momentum=0.9,
                     momentum_window=4)
    strat = build_strategy("vmapdir", "momentum")
    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    records = []
    for step in range(6):
        state, aux = strat.step(loss_fn, state, None, jnp.uint32(step), cfg)
        records.append({"step": step, "seed": int(np.asarray(aux.seed)),
                        "gs": np.asarray(aux.gs, np.float32).tolist(),
                        "lr": 1e-2, "eps": 1e-3})

    store = AdapterStore(params, cfg, update_rule=strat.update)
    store.put("u", records)
    for a, b in zip(jax.tree.leaves(store.materialize("u")),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    sgd_store = AdapterStore(params, cfg)      # wrong rule: must differ
    sgd_store.put("u", records)
    diff = max(np.max(np.abs(np.asarray(a, np.float32)
                             - np.asarray(b, np.float32)))
               for a, b in zip(jax.tree.leaves(sgd_store.materialize("u")),
                               jax.tree.leaves(state.params)))
    assert diff > 0


def test_adapter_lru_eviction_and_hits():
    base = _tiny_params()
    budget = 2 * tree_bytes(base) + 16     # room for ~2 materialized trees
    store = AdapterStore(base, MezoConfig(n_directions=2),
                         cache_bytes=budget)
    for i, u in enumerate(("u0", "u1", "u2")):
        store.put(u, _synthetic_records(3, seed=i))
        store.materialize(u)
    assert store.stats["misses"] == 3
    assert store.stats["evictions"] >= 1
    assert store.cached_bytes() <= budget
    store.materialize("u2")                       # most recent: still hot
    assert store.stats["hits"] == 1
    store.materialize("u0")                       # evicted: replays again
    assert store.stats["misses"] == 4


def test_adapter_save_load_roundtrip(tmp_path):
    base = _tiny_params()
    store = AdapterStore(base, MezoConfig(n_directions=2))
    store.put("u", _synthetic_records(4))
    mat = store.materialize("u")
    store.save("u", str(tmp_path / "u.jsonl"))

    other = AdapterStore(base, MezoConfig(n_directions=2))
    other.load("u", str(tmp_path / "u.jsonl"))
    for a, b in zip(jax.tree.leaves(mat),
                    jax.tree.leaves(other.materialize("u"))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adapter_int8_delta_form(tmp_path):
    base = _tiny_params()
    store = AdapterStore(base, MezoConfig(n_directions=2))
    store.put("u", _synthetic_records(4))
    mat = store.materialize("u")
    store.save_delta("u", str(tmp_path / "u_delta.npz"))

    compact = AdapterStore(base, MezoConfig(n_directions=2))
    compact.load_delta("u", str(tmp_path / "u_delta.npz"))
    approx = compact.materialize("u")
    for a, b, bb in zip(jax.tree.leaves(mat), jax.tree.leaves(approx),
                        jax.tree.leaves(base)):
        d = np.abs(np.asarray(a, np.float32) - np.asarray(bb, np.float32))
        tol = d.max() / 127.0 + 1e-7      # one int8 roundtrip per leaf
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32), atol=tol)


def test_adapter_unknown_user_raises():
    store = AdapterStore(_tiny_params())
    with pytest.raises(KeyError):
        store.materialize("nobody")
    assert store.materialize(None) is store.base


# ---------------------------------------------------------------------------
# engine: multi-adapter interleaving + seeded sampling


def test_engine_interleaves_two_adapters_and_seeds_sampling():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    store = AdapterStore(base, MezoConfig(n_directions=2))
    store.put("alice", _synthetic_records(6, seed=1))
    store.put("bob", _synthetic_records(6, seed=2))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (6,),
                                           0, cfg.vocab), np.int32)

    def run(seed, greedy):
        eng = ServeEngine(cfg, store, n_slots=2, max_len=16, seed=seed)
        rids = [eng.submit(Request(prompt=prompt, max_new=4, user=u,
                                   greedy=greedy, topk=8))
                for u in ("alice", "bob", "alice")]   # 3 reqs, 2 slots
        outs = {c.rid: c for c in eng.run()}
        assert [outs[r].user for r in rids] == ["alice", "bob", "alice"]
        return [outs[r].tokens.tolist() for r in rids]

    g = run(0, greedy=True)
    assert g == run(7, greedy=True)        # greedy ignores the seed
    assert g[0] == g[2]                    # same adapter, same prompt
    s0, s0b, s1 = run(0, False), run(0, False), run(1, False)
    assert s0 == s0b                       # seeded sampling is reproducible
    assert s0 != s1 or s0[0] != g[0]       # and actually samples


def test_engine_rejects_oversized_request():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    eng = ServeEngine(cfg, AdapterStore(model.init(jax.random.PRNGKey(0))),
                      n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(6, np.int32), max_new=4))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(2, np.int32), max_new=0))


# ---------------------------------------------------------------------------
# chunked prefill: staggered arrivals, observability


def _staggered_serve(cfg, store, prefill_chunk=None):
    """Two adapters decoding, then a long-prompt base request arriving
    mid-flight -- the admission-stall scenario chunked prefill exists
    for. Returns {rid: tokens} plus the engine for stats assertions."""
    eng = ServeEngine(cfg, store, n_slots=3, max_len=40, seed=0,
                      paged=True, page_size=4,
                      prefill_chunk=prefill_chunk)
    rng = np.random.default_rng(7)
    mk = lambda p, u: Request(
        prompt=rng.integers(0, cfg.vocab, p).astype(np.int32),
        max_new=6, user=u)
    eng.submit(mk(5, "alice"))
    eng.submit(mk(7, "bob"))
    out = []
    for _ in range(3):                    # both slots mid-decode
        eng.step()
        out.extend(eng.drain_finished())
    eng.submit(mk(23, None))              # long prompt arrives
    eng.submit(mk(6, "alice"))
    while eng.queue or eng._active.any() or eng._prefill_slot is not None:
        eng.step()
        out.extend(eng.drain_finished())
    return {c.rid: c.tokens.tolist() for c in out}, eng, out


def test_chunked_prefill_staggered_multi_adapter_parity():
    """Greedy tokens bit-identical chunked vs whole-prompt admission
    when a long prompt lands mid-decode across two resident adapters,
    for chunk sizes that leave the admission in flight over several
    engine steps."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("alice", _synthetic_records(4, seed=1))
    store.put("bob", _synthetic_records(4, seed=2))
    whole, _, _ = _staggered_serve(cfg, store)
    for chunk in (2, 5):
        got, eng, _ = _staggered_serve(cfg, store, prefill_chunk=chunk)
        assert got == whole
        assert eng.stats.prefill_tokens == 5 + 7 + 23 + 6


def test_engine_latency_observability():
    """queue_wait_s / ttft_s per completion (submit -> admission start /
    first token) and the decode_stall_s counter: present, ordered, and
    consistent with the stats totals."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("alice", _synthetic_records(4, seed=1))
    store.put("bob", _synthetic_records(4, seed=2))
    _, eng, comps = _staggered_serve(cfg, store)
    assert len(comps) == 4
    for c in comps:
        assert 0.0 <= c.queue_wait_s <= c.ttft_s
    assert eng.stats.ttft_s == pytest.approx(sum(c.ttft_s for c in comps))
    assert eng.stats.queue_wait_s == pytest.approx(
        sum(c.queue_wait_s for c in comps))
    # three slots decoded while the 23-token prompt prefilled whole: the
    # admission stall must be visible (chunked admission shrinks it)
    assert eng.stats.decode_stall_s > 0.0
