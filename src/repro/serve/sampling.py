"""Per-slot token sampling: greedy and seeded top-k.

The engine owns ONE PRNG key and splits it per decode step; the step key
is folded with the slot index so every slot draws from an independent
stream. This replaces the old ``PRNGKey(loop_index)`` pattern, which
rebuilt the key from the step counter -- identical across runs and
correlated across requests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnums=(1,))
def step_keys(key, n_slots: int):
    """Advance the engine key one step; returns (new_key, (n_slots, ...)
    per-slot keys)."""
    key, sub = jax.random.split(key)
    slot_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        sub, jnp.arange(n_slots, dtype=jnp.uint32))
    return key, slot_keys


def greedy(logits):
    """logits: (B, V) -> (B,) argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnums=(2,))
def sample_topk(keys, logits, k: int, temperature=1.0):
    """Seeded top-k sampling, vectorized over slots.

    keys: (B, ...) per-slot keys (from :func:`step_keys`); logits: (B, V).
    Renormalizes over the k largest logits, scaled by ``temperature``.
    """
    k = max(1, min(k, logits.shape[-1]))
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    t = jnp.maximum(jnp.float32(temperature), 1e-6)

    def one(kk, vv, ii):
        return ii[jax.random.categorical(kk, vv / t)]

    return jax.vmap(one)(keys, vals, idx).astype(jnp.int32)
