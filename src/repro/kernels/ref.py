"""Pure-jnp oracles for the ZO Pallas kernels.

These share the hash RNG with repro.core.rng (same avalanche, same
per-dimension primes), so kernel-vs-ref comparisons are bit-exact in f32
for rademacher and allclose for gaussian/matmul accumulation order.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rng as zrng


def z_ref(seed, salt: int, shape, dist: str = "rademacher"):
    return zrng.z_field(seed, salt, shape, jnp.float32, dist)


def zo_add_ref(w, seed, salt: int, coeff, dist: str = "rademacher"):
    z = z_ref(seed, salt, w.shape, dist)
    return (w.astype(jnp.float32) + jnp.float32(coeff) * z).astype(w.dtype)


def zo_matmul_ref(x, w, seed, salt: int, coeff, dist: str = "rademacher"):
    z = z_ref(seed, salt, w.shape, dist)
    wp = w.astype(jnp.float32) + jnp.float32(coeff) * z
    return (x.astype(jnp.float32) @ wp).astype(x.dtype)
