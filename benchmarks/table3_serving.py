"""Table 3 (serving): fused prefill vs per-token loop, continuous-
batching decode throughput, and ZO-adapter materialization latency.

The paper stops at fine-tuning on the device; the serving subsystem
(src/repro/serve) closes the loop -- this table gives the perf
trajectory a serving baseline. All numbers are reduced-config CPU (same
caveat as table2: kernels are TPU-targeted; relative effects are what
transfer).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.models import build_model
from repro.serve import AdapterStore, Request, ServeEngine


def _timeit(fn, n=5):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows, table = [], {}

    # ---- prefill: fused single-call vs per-token decode loop ------------
    B, P, G = 4, 48, 16
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (B, P),
                                                dtype=np.int32)
    toks = jnp.asarray(prompts)
    step = jax.jit(model.decode_step)
    prefill = jax.jit(model.prefill)

    def loop_prefill():
        cache = model.init_cache(B, P + G)
        lg = None
        for t in range(P):
            lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        jax.block_until_ready(lg)

    def fused_prefill():
        cache = model.init_cache(B, P + G)
        lg, cache = prefill(params, cache, toks)
        jax.block_until_ready(lg)

    s_loop = _timeit(loop_prefill)
    s_fused = _timeit(fused_prefill)
    tps_loop, tps_fused = B * P / s_loop, B * P / s_fused
    speedup = tps_fused / tps_loop
    rows.append(("table3/prefill_loop", s_loop * 1e6,
                 f"{tps_loop:.0f} tok/s ({P} decode_step dispatches)"))
    rows.append(("table3/prefill_fused", s_fused * 1e6,
                 f"{tps_fused:.0f} tok/s ({speedup:.1f}x over loop)"))
    table["prefill"] = {"batch": B, "prompt_len": P,
                        "loop_tok_per_s": tps_loop,
                        "fused_tok_per_s": tps_fused, "speedup": speedup}

    # ---- adapters: materialization latency vs cache hit -----------------
    mz = MezoConfig(eps=1e-2, lr=5e-3, n_directions=4)
    store = AdapterStore(params, mz)
    rng = np.random.default_rng(1)
    n_steps = 50
    for u in ("u0", "u1"):
        store.put(u, [{"step": i, "seed": int(rng.integers(2**31)),
                       "gs": rng.normal(size=4).astype(np.float32).tolist(),
                       "lr": 5e-3, "eps": 1e-2} for i in range(n_steps)])
    t0 = time.perf_counter()
    store.materialize("u0")
    cold = time.perf_counter() - t0
    hit = _timeit(lambda: store.materialize("u0"), n=20)
    rows.append(("table3/adapter_materialize_cold", cold * 1e6,
                 f"{n_steps}-step replay from base (zero forward passes)"))
    rows.append(("table3/adapter_cache_hit", hit * 1e6, "LRU-cached tree"))
    table["adapter"] = {"replay_steps": n_steps, "cold_s": cold,
                        "hit_s": hit,
                        "adapter_bytes": store._adapters["u0"].nbytes}

    # ---- continuous-batching decode throughput --------------------------
    def decode_run(users):
        eng = ServeEngine(cfg, store, n_slots=B, max_len=P + G, seed=0)
        for i in range(B):
            eng.submit(Request(prompt=prompts[i], max_new=G,
                               user=users[i % len(users)]))
        eng.run()
        return eng.stats

    decode_run(["u0"]), decode_run(["u0", "u1"])   # compile both paths
    st1 = decode_run(["u0"])               # one adapter: one dispatch/step
    st2 = decode_run(["u0", "u1"])         # two adapters: masked merge
    rows.append(("table3/decode_1adapter", st1.decode_s / max(
        st1.decode_steps, 1) * 1e6, f"{st1.decode_tps:.0f} tok/s"))
    rows.append(("table3/decode_2adapters", st2.decode_s / max(
        st2.decode_steps, 1) * 1e6,
        f"{st2.decode_tps:.0f} tok/s (per-adapter masked dispatch)"))
    table["decode"] = {"slots": B, "gen": G,
                       "one_adapter_tok_per_s": st1.decode_tps,
                       "two_adapter_tok_per_s": st2.decode_tps,
                       "engine_prefill_tok_per_s": st1.prefill_tps}

    # ---- long-generation decode: dense vs paged KV ----------------------
    # gen >> prompt is where decode dominates and where the paged read
    # (live pages only) beats the dense full-max_len cache scan. Tokens
    # must match bit-for-bit: paging relayouts the cache, not the math.
    LG = 256
    PS = 16

    def long_run(paged):
        eng = ServeEngine(cfg, store, n_slots=B, max_len=P + LG, seed=0,
                          paged=paged, page_size=PS)
        rids = [eng.submit(Request(prompt=prompts[i], max_new=LG,
                                   user="u0")) for i in range(B)]
        outs = {c.rid: c.tokens.tolist() for c in eng.run()}
        return eng.stats, [outs[r] for r in rids]

    long_run(False), long_run(True)        # compile both layouts
    st_d, toks_d = long_run(False)
    st_p, toks_p = long_run(True)
    parity = toks_d == toks_p
    rows.append(("table3/decode_long_dense", st_d.decode_s / max(
        st_d.decode_steps, 1) * 1e6, f"{st_d.decode_tps:.0f} tok/s "
        f"(gen={LG}, dense KV)"))
    rows.append(("table3/decode_long_paged", st_p.decode_s / max(
        st_p.decode_steps, 1) * 1e6, f"{st_p.decode_tps:.0f} tok/s "
        f"(gen={LG}, page_size={PS}, parity={parity})"))
    table["decode_long"] = {
        "slots": B, "prompt_len": P, "gen": LG, "page_size": PS,
        "dense_tok_per_s": st_d.decode_tps,
        "paged_tok_per_s": st_p.decode_tps,
        "paged_greedy_parity": parity,
        "paged_peak_pages": st_p.peak_pages_in_use}

    # ---- self-speculative decoding over shared pages --------------------
    # The paper's regime: every slot a DIFFERENT user's small ZO delta.
    # Plain decode pays one masked dispatch per distinct adapter per
    # token; speculation drafts with the shared base (one adapter-free
    # dispatch advances every slot k tokens) and pays the per-adapter
    # dispatch once per k+1-token verify window. Small personalization
    # deltas keep draft ~= target, so acceptance -- and the speedup --
    # stays high. Greedy tokens must match the plain engine bit-for-bit.
    SK = 4
    spec_users = [f"u_spec{i}" for i in range(B)]
    for u in spec_users:
        store.put(u, [{"step": i, "seed": int(rng.integers(2**31)),
                       "gs": rng.normal(size=4).astype(np.float32).tolist(),
                       "lr": 1e-4, "eps": 1e-2} for i in range(8)])

    def spec_run(spec_k):
        eng = ServeEngine(cfg, store, n_slots=B, max_len=P + LG, seed=0,
                          paged=True, page_size=PS, spec_k=spec_k)
        rids = [eng.submit(Request(prompt=prompts[i], max_new=LG,
                                   user=spec_users[i])) for i in range(B)]
        outs = {c.rid: c.tokens.tolist() for c in eng.run()}
        return eng.stats, [outs[r] for r in rids]

    spec_run(None), spec_run(SK)           # compile both paths
    st_plain, toks_plain = spec_run(None)
    st_spec, toks_spec = spec_run(SK)
    spec_parity = toks_plain == toks_spec
    spec_speedup = st_spec.decode_tps / max(st_plain.decode_tps, 1e-9)
    rows.append(("table3/decode_spec_plain", st_plain.decode_s / max(
        st_plain.decode_steps, 1) * 1e6, f"{st_plain.decode_tps:.0f} tok/s "
        f"({B} adapters, gen={LG})"))
    rows.append(("table3/decode_spec", st_spec.decode_s / max(
        st_spec.decode_steps, 1) * 1e6, f"{st_spec.decode_tps:.0f} tok/s "
        f"({spec_speedup:.1f}x, k={SK}, accept="
        f"{st_spec.spec_accept_rate:.2f}, parity={spec_parity})"))
    table["decode_spec"] = {
        "slots": B, "adapters": B, "gen": LG, "spec_k": SK,
        "page_size": PS,
        "plain_tok_per_s": st_plain.decode_tps,
        "spec_tok_per_s": st_spec.decode_tps,
        "speedup": spec_speedup,
        "accept_rate": st_spec.spec_accept_rate,
        "drafted": st_spec.spec_drafted,
        "accepted": st_spec.spec_accepted,
        "spec_rounds": st_spec.decode_steps,
        "greedy_parity": spec_parity}

    # ---- resident slots at a fixed KV HBM budget ------------------------
    # budget = the dense engine's 4 slots x max_len KV. The paged pool
    # holds the same page count but shares it: short requests occupy
    # only their live pages, so far more of them are resident at once.
    slot_pages = -(-(P + LG) // PS)
    pool = B * slot_pages + 1              # == dense KV bytes (+ trash)
    many = 4 * B
    short_p, short_g = 16, 16              # 32 tokens -> 2 pages each
    eng = ServeEngine(cfg, store, n_slots=many, max_len=P + LG, seed=0,
                      paged=True, page_size=PS, pool_pages=pool)
    sp = np.random.default_rng(2).integers(0, cfg.vocab, (many, short_p),
                                           dtype=np.int32)
    for i in range(many):
        eng.submit(Request(prompt=sp[i], max_new=short_g, user="u0"))
    eng.run()
    ratio = eng.stats.peak_active_slots / B
    rows.append(("table3/resident_slots_fixed_hbm",
                 eng.stats.peak_active_slots,
                 f"{eng.stats.peak_active_slots} slots vs {B} dense "
                 f"({ratio:.1f}x) at {pool - 1} pages"))
    table["resident_slots"] = {
        "kv_budget_pages": pool - 1, "dense_slots": B,
        "paged_peak_active_slots": eng.stats.peak_active_slots,
        "slots_ratio": ratio,
        "request_tokens": short_p + short_g,
        "paged_peak_pages": eng.stats.peak_pages_in_use}

    # ---- mixed load: long-prompt arrivals vs resident decoders ----------
    # The admission-stall scenario: short interactive requests are
    # mid-decode when a long prompt arrives. Whole-prompt admission
    # freezes every decoding slot for the full prefill; chunked
    # admission (prefill_chunk=C) advances the prompt C tokens per
    # engine step while the decoders keep stepping -- nearly-finished
    # slots drain instead of stalling. decode_stall_s (slot-seconds
    # decoders sat idle during admission prefill work, same accounting
    # in both modes) is the gated figure; TTFT percentiles are over all
    # completions. Greedy tokens must match bit-for-bit.
    WAVES, SHORTS, SP, SG = 3, 3, 8, 4
    LP, LGEN, CC = 320, 4, 16
    ML = LP + LGEN + 8
    rng2 = np.random.default_rng(3)
    short_p = rng2.integers(0, cfg.vocab, (WAVES, SHORTS, SP),
                            dtype=np.int32)
    long_p = rng2.integers(0, cfg.vocab, (WAVES, LP), dtype=np.int32)

    def mixed_run(prefill_chunk):
        eng = ServeEngine(cfg, store, n_slots=SHORTS + 1, max_len=ML,
                          seed=0, paged=True, page_size=PS,
                          prefill_chunk=prefill_chunk)
        comps = []
        for w in range(WAVES):
            for i in range(SHORTS):
                eng.submit(Request(prompt=short_p[w, i], max_new=SG,
                                   user="u0"))
            for _ in range(2):             # shorts reach mid-decode
                eng.step()
                comps.extend(eng.drain_finished())
            eng.submit(Request(prompt=long_p[w], max_new=LGEN, user="u0"))
            while (eng.queue or eng._active.any()
                   or eng._prefill_slot is not None):
                eng.step()
                comps.extend(eng.drain_finished())
        toks = {c.rid: c.tokens.tolist() for c in comps}
        ttfts = np.asarray([c.ttft_s for c in comps])
        return eng.stats, toks, ttfts

    mixed_run(None), mixed_run(CC)         # compile both admission paths
    st_w, toks_w, ttft_w = mixed_run(None)
    st_c, toks_c, ttft_c = mixed_run(CC)
    mixed_parity = toks_w == toks_c
    stall_ratio = st_w.decode_stall_s / max(st_c.decode_stall_s, 1e-9)
    rows.append(("table3/mixed_load_whole", st_w.decode_stall_s * 1e6,
                 f"stall {st_w.decode_stall_s:.3f} slot-s, ttft p99 "
                 f"{np.percentile(ttft_w, 99) * 1e3:.0f}ms "
                 f"(whole-prompt admission)"))
    rows.append(("table3/mixed_load_chunked", st_c.decode_stall_s * 1e6,
                 f"stall {st_c.decode_stall_s:.3f} slot-s "
                 f"({stall_ratio:.1f}x lower, C={CC}, ttft p99 "
                 f"{np.percentile(ttft_c, 99) * 1e3:.0f}ms, "
                 f"parity={mixed_parity})"))
    table["mixed_load"] = {
        "waves": WAVES, "short_requests": WAVES * SHORTS,
        "short_tokens": SP + SG, "long_prompt": LP, "long_gen": LGEN,
        "prefill_chunk": CC, "page_size": PS,
        "whole_decode_stall_s": st_w.decode_stall_s,
        "chunked_decode_stall_s": st_c.decode_stall_s,
        "stall_ratio": stall_ratio,
        "whole_ttft_p50_ms": float(np.percentile(ttft_w, 50) * 1e3),
        "whole_ttft_p99_ms": float(np.percentile(ttft_w, 99) * 1e3),
        "chunked_ttft_p50_ms": float(np.percentile(ttft_c, 50) * 1e3),
        "chunked_ttft_p99_ms": float(np.percentile(ttft_c, 99) * 1e3),
        "greedy_parity": mixed_parity}

    with open(os.path.join(out_dir, "table3_serving.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
