"""Elastic re-meshing (single-device rendering of the pod join/leave path)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.elastic import elastic_mesh, remesh_params


def test_degraded_single_device_mesh():
    mesh = elastic_mesh(jax.devices(), model_parallel=16, data_parallel=16)
    assert mesh.axis_names == ("pod", "data", "model")
    assert int(np.prod(mesh.devices.shape)) == len(jax.devices())


def test_remesh_params_identity_on_one_device():
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((4,))}
    mesh = elastic_mesh(jax.devices(), model_parallel=1, data_parallel=1)
    out = remesh_params(params, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
