"""Paged KV serving: runtime-level paged/dense decode parity, engine
greedy bit-parity paged vs unpaged across families, and page-pool
accounting (reservation admission, growth, free-on-finish).

Set REPRO_FAMILY=<family[,family]> to restrict the engine parity matrix
(the CI family matrix does).
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import AdapterStore, Request, ServeEngine

_FAM = os.environ.get("REPRO_FAMILY")
# arch -> family, mirroring launch.serve.FAMILY_ARCHS (rwkv6 pins the
# no-pageable-state degenerate path; jamba pins paged attention pools
# coexisting with dense mamba recurrent state in one cache)
ENGINE_ARCHS = {"gemma-2b": "dense", "rwkv6-7b": "ssm",
                "jamba-v0.1-52b": "hybrid"}
ARCHS = [a for a, f in ENGINE_ARCHS.items()
         if not _FAM or f in _FAM.split(",")]


def _records(n, k=2, seed=0):
    rng = np.random.default_rng(seed)
    return [{"step": i, "seed": int(rng.integers(2**31)),
             "gs": rng.normal(size=k).astype(np.float32).tolist(),
             "lr": 5e-2, "eps": 1e-2} for i in range(n)]


# ---------------------------------------------------------------------------
# runtime level: paged decode_step == dense decode_step


def test_runtime_paged_decode_matches_dense():
    """Same tokens through a paged cache (scrambled page table, ragged
    per-slot positions straddling page boundaries) and a dense cache
    must produce the same logits every step."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, ps, n_live = 3, 4, 4
    pos0 = np.array([ps - 1, ps, 2 * ps + 3], np.int32)  # boundary cases
    max_len = int(pos0.max()) + 10

    dense = model.init_cache(B, max_len)
    paged = model.init_paged_cache(B, 1 + B * n_live, ps, max_len=max_len)
    rng = np.random.default_rng(1)
    perm = rng.permutation(np.arange(1, 1 + B * n_live))
    table = perm.reshape(B, n_live).astype(np.int32)

    # build matching histories: replay each slot's prefix token-by-token
    # through both caches (dense scalar-pos decode vs paged decode)
    hist = rng.integers(0, cfg.vocab, (B, max_len), dtype=np.int32)
    for b in range(B):
        for t in range(int(pos0[b])):
            tok = jnp.asarray(hist[b:b + 1, t:t + 1])
            one_d = model.init_cache(1, max_len) if t == 0 else one_d
            _, one_d = model.decode_step(params, one_d, tok, jnp.int32(t))
        if pos0[b]:
            dense = jax.tree.map(
                lambda c, r: c.at[:, b].set(r[:, 0]), dense, one_d)
    # paged prefix: vector-pos decode over all slots at once
    pos = np.zeros(B, np.int32)
    pages = jnp.asarray(table)
    for t in range(int(pos0.max())):
        mask = pos0 > t
        toks = jnp.asarray(hist[:, t:t + 1])
        _, new = model.decode_step(params, paged, toks, jnp.asarray(pos),
                                   pages=pages,
                                   write_mask=jnp.asarray(mask))
        paged = new
        pos = np.where(mask, pos + 1, pos)
    assert (pos == pos0).all()

    steps = rng.integers(0, cfg.vocab, (B, 4), dtype=np.int32)
    for step in range(4):
        toks = jnp.asarray(steps[:, step:step + 1])
        ld, dense = model.decode_step(params, dense, toks,
                                      jnp.asarray(pos))
        lp, paged = model.decode_step(params, paged, toks,
                                      jnp.asarray(pos), pages=pages)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                                   rtol=2e-4, atol=2e-5)
        pos = pos + 1


def test_init_paged_cache_layouts():
    """Attention K/V becomes pool leaves; recurrent state stays dense;
    rwkv6 has nothing to page at all."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    model = build_model(cfg)
    cache = model.init_paged_cache(2, 9, 4, max_len=32)
    leaves = {str(getattr(p[-1], "key", p[-1])): l.shape for p, l in
              jax.tree_util.tree_leaves_with_path(cache)}
    assert any(n == "k_pages" and s[1:3] == (9, 4)
               for n, s in leaves.items())
    assert any(n in ("conv", "ssm") and s[1] == 2    # batch axis intact
               for n, s in leaves.items())
    assert build_model(get_config("rwkv6-7b").reduced()).init_paged_cache \
        is None


# ---------------------------------------------------------------------------
# engine level: paged == unpaged, bit for bit


def _run_engine(cfg, store, paged, plens, G, users=None, n_slots=2,
                page_size=4, pool_pages=None):
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (p,), 0, cfg.vocab), np.int32)
               for i, p in enumerate(plens)]
    eng = ServeEngine(cfg, store, n_slots=n_slots, max_len=max(plens) + G,
                      seed=0, paged=paged, page_size=page_size,
                      pool_pages=pool_pages)
    rids = [eng.submit(Request(prompt=pr, max_new=G,
                               user=users[i] if users else None))
            for i, pr in enumerate(prompts)]
    outs = {c.rid: c.tokens.tolist() for c in eng.run()}
    return [outs[r] for r in rids], eng


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_paged_matches_unpaged(arch):
    """Greedy tokens must be bit-identical with and without paging --
    staggered prompt lengths, more requests than slots (mid-flight
    admission into recycled pages)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    store = AdapterStore(model.init(jax.random.PRNGKey(0)))
    plens, G = (5, 9, 7, 12), 6
    a, _ = _run_engine(cfg, store, False, plens, G)
    b, eng = _run_engine(cfg, store, True, plens, G)
    assert a == b
    if eng.paged:   # rwkv6 degenerates to the dense layout
        assert eng.stats.peak_pages_in_use > 0
        assert len(eng._free_pages) == eng.pool_pages - 1  # all freed


def test_engine_paged_matches_unpaged_multi_adapter():
    """Masked per-adapter dispatch + trash-page scatter: mixed base /
    alice / bob slots stay bit-identical to the unpaged engine."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    store = AdapterStore(model.init(jax.random.PRNGKey(0)))
    store.put("alice", _records(4, seed=1))
    store.put("bob", _records(4, seed=2))
    users = [None, "alice", "bob", "alice"]
    a, _ = _run_engine(cfg, store, False, (5, 9, 7, 12), 6, users=users)
    b, _ = _run_engine(cfg, store, True, (5, 9, 7, 12), 6, users=users)
    assert a == b


# ---------------------------------------------------------------------------
# page-pool accounting


def test_pool_exhaustion_queues_then_completes():
    """A pool smaller than slots x max_len admits only what fits; queued
    requests proceed as finishing slots free pages, and every request
    still completes with full-length output."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    store = AdapterStore(model.init(jax.random.PRNGKey(0)))
    plens, G = (6, 6, 6, 6, 6), 5     # 11 tokens -> 3 pages each @ ps=4
    outs, eng = _run_engine(cfg, store, True, plens, G, n_slots=4,
                            pool_pages=7)         # 6 usable: 2 in flight
    assert all(len(o) == G for o in outs)
    assert eng.stats.peak_active_slots == 2       # pool, not slots, bound
    assert eng.stats.peak_pages_in_use <= 6
    assert eng._reserved == 0 and len(eng._free_pages) == 6
    unpaged, _ = _run_engine(cfg, store, False, plens, G, n_slots=4)
    assert outs == unpaged                        # queueing changes order
    #                                               of work, not tokens

def test_oversized_request_rejected_at_submit():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    eng = ServeEngine(cfg, AdapterStore(model.init(jax.random.PRNGKey(0))),
                      n_slots=2, max_len=24, paged=True, page_size=4,
                      pool_pages=5)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(prompt=np.zeros(6, np.int32), max_new=12))


# ---------------------------------------------------------------------------
# chunked prefill: whole-prompt admission vs prefill_chunk=C, bit for bit


def _run_chunked(cfg, store, plens, G, prefill_chunk=None, users=None,
                 n_slots=2, page_size=4):
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (p,), 0, cfg.vocab), np.int32)
               for i, p in enumerate(plens)]
    eng = ServeEngine(cfg, store, n_slots=n_slots, max_len=max(plens) + G,
                      seed=0, paged=True, page_size=page_size,
                      prefill_chunk=prefill_chunk)
    if prefill_chunk:
        # no dense B=1 prompt cache may exist on the chunked admission
        # path: chunks write straight into the pool, install never runs
        def _boom(*a, **kw):
            raise AssertionError("dense prefill path used in chunked mode")
        eng.model = dataclasses.replace(eng.model, init_cache=_boom)
        eng._fns = {**eng._fns, "prefill": _boom, "install": _boom,
                    "install_paged": _boom}
    rids = [eng.submit(Request(prompt=pr, max_new=G,
                               user=users[i] if users else None))
            for i, pr in enumerate(prompts)]
    outs = {c.rid: c.tokens.tolist() for c in eng.run()}
    return [outs[r] for r in rids], eng


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "rwkv6-7b"])
@pytest.mark.parametrize("chunk", [1, 3, 4, 64])
def test_engine_chunked_prefill_matches_whole_prompt(arch, chunk):
    """Greedy tokens must be bit-identical whether a prompt is admitted
    in one whole-prompt prefill or spread over C-token chunks written
    straight into the pool -- chunk sizes below, at, and above the page
    size, tails decomposing into pow2 pieces (plen 9 @ C=4 -> 4+4+1),
    and C=64 > every prompt (one chunk, still the paged path)."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # chunked admission re-times the decode batches, and MoE expert
        # capacity is contended across whatever shares a dispatch --
        # ample capacity keeps routing deterministic so parity is about
        # the chunk path, not capacity drops (cf. test_serve.py)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    store = AdapterStore(model.init(jax.random.PRNGKey(0)))
    plens, G = (5, 9, 7, 12), 6
    a, _ = _run_chunked(cfg, store, plens, G)
    b, eng = _run_chunked(cfg, store, plens, G, prefill_chunk=chunk)
    assert a == b
    assert eng.stats.prefill_tokens == sum(plens)
    assert eng._prefill_slot is None
    assert len(eng._free_pages) == eng.pool_pages - 1    # all pages freed
    assert eng._reserved == 0


def test_engine_chunked_prefill_multi_adapter():
    """Chunked admission under mixed base / alice / bob slots: the
    in-flight prefill slot must survive masked multi-adapter decode
    dispatches between its chunks (trash-page writes for the masked
    lane), staying bit-identical to whole-prompt admission."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    store = AdapterStore(model.init(jax.random.PRNGKey(0)))
    store.put("alice", _records(4, seed=1))
    store.put("bob", _records(4, seed=2))
    users = [None, "alice", "bob", "alice"]
    plens, G = (5, 9, 7, 12), 6
    a, _ = _run_chunked(cfg, store, plens, G, users=users)
    b, _ = _run_chunked(cfg, store, plens, G, prefill_chunk=3, users=users)
    assert a == b


def test_chunked_prefill_flag_validation():
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="prefill_chunk must be >= 1"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=True,
                    prefill_chunk=0)
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=False,
                    prefill_chunk=4)


def test_chunked_prefill_rejected_without_pageable_state():
    """rwkv6 degrades paged=True to the dense layout -- there are no
    pages for chunks to write into, so prefill_chunk must be a loud
    constructor error, not a silent whole-prompt fallback."""
    cfg = get_config("rwkv6-7b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="no pageable state"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=True,
                    prefill_chunk=4)
