from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.stragglers import StragglerPolicy
from repro.runtime.elastic import elastic_mesh, remesh_params

__all__ = ["Trainer", "TrainerConfig", "StragglerPolicy", "elastic_mesh",
           "remesh_params"]
