from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.stragglers import StragglerPolicy
from repro.runtime.elastic import elastic_mesh, mesh_shape_for, remesh_params
from repro.runtime.fleet import (DirectionLease, FaultSpec, FleetCoordinator,
                                 FleetReport, FleetSim, WorkerSpec,
                                 get_grade, lease_latency_s)

__all__ = ["Trainer", "TrainerConfig", "StragglerPolicy", "elastic_mesh",
           "mesh_shape_for", "remesh_params", "FleetCoordinator", "FleetSim",
           "FleetReport", "DirectionLease", "WorkerSpec", "FaultSpec",
           "get_grade", "lease_latency_s"]
