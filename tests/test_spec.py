"""Self-speculative decoding: greedy engine output must be bit-identical
to the plain paged engine (the base drafts, base+delta verifies over the
same pages), across dense and hybrid families, multi-adapter
interleaving, quantized int8 bases, and every spec_k regime (k=1, the
default, k far beyond max_new). Plus flag validation, acceptance
accounting, and the sampled-slot path.

Set REPRO_FAMILY=<family[,family]> to restrict the family matrix (the
CI family matrix does). rwkv6 (ssm) has no pageable state, so its only
spec behavior is the constructor rejection pinned below.
"""

import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.optim.quant import quantize_tree
from repro.serve import AdapterStore, Request, ServeEngine

_FAM = os.environ.get("REPRO_FAMILY")
# spec parity is pinned for dense + hybrid only: MoE expert capacity is
# shared across window offsets (engine docstring), rwkv6 has no pages
SPEC_ARCHS = {"gemma-2b": "dense", "jamba-v0.1-52b": "hybrid"}
ARCHS = [a for a, f in SPEC_ARCHS.items()
         if not _FAM or f in _FAM.split(",")]


def _records(n, k=2, seed=0, lr=5e-2):
    rng = np.random.default_rng(seed)
    return [{"step": i, "seed": int(rng.integers(2**31)),
             "gs": rng.normal(size=k).astype(np.float32).tolist(),
             "lr": lr, "eps": 1e-2} for i in range(n)]


def _prompts(cfg, plens):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                          (p,), 0, cfg.vocab), np.int32)
            for i, p in enumerate(plens)]


def _run(cfg, store, plens, G, spec_k=None, users=None, n_slots=2,
         seed=0, **req_kw):
    eng = ServeEngine(cfg, store, n_slots=n_slots, max_len=max(plens) + G,
                      seed=seed, paged=True, page_size=4, spec_k=spec_k)
    rids = [eng.submit(Request(prompt=pr, max_new=G,
                               user=users[i] if users else None, **req_kw))
            for i, pr in enumerate(_prompts(cfg, plens))]
    comps = {c.rid: c for c in eng.run()}
    return [comps[r].tokens.tolist() for r in rids], eng, \
        [comps[r] for r in rids]


# ---------------------------------------------------------------------------
# greedy bit-parity


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("spec_k", [1, 3, 99])
def test_spec_matches_plain_greedy(arch, spec_k):
    """Staggered prompts, more requests than slots (mid-flight admission
    into recycled pages), windows truncated by remaining (spec_k=99 >
    max_new). Every greedy token must be bit-identical to the plain
    paged engine."""
    cfg = get_config(arch).reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("u", _records(4, seed=1))
    users = ["u", None, "u", None]
    plens, G = (5, 9, 7, 12), 6
    a, _, _ = _run(cfg, store, plens, G, users=users)
    b, eng, comps = _run(cfg, store, plens, G, spec_k=spec_k, users=users)
    assert a == b
    assert eng.stats.spec_drafted > 0
    assert 0.0 <= eng.stats.spec_accept_rate <= 1.0
    assert eng.stats.decode_tokens == sum(len(t) for t in a) - len(a)
    # spec rounds commit >= 1 token each: fewer steps than plain decode
    assert eng.stats.decode_steps <= eng.stats.decode_tokens
    for c in comps:
        assert c.accept_rate is not None and 0.0 <= c.accept_rate <= 1.0
    assert len(eng._free_pages) == eng.pool_pages - 1    # all pages freed
    assert eng._reserved == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_matches_plain_multi_adapter(arch):
    """Per-adapter verify dispatch + per-user commit: mixed base / alice
    / bob slots interleaved in one batch stay bit-identical."""
    cfg = get_config(arch).reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("alice", _records(4, seed=1))
    store.put("bob", _records(4, seed=2))
    users = [None, "alice", "bob", "alice"]
    plens, G = (5, 9, 7, 12), 6
    a, _, _ = _run(cfg, store, plens, G, users=users)
    b, _, _ = _run(cfg, store, plens, G, spec_k=3, users=users)
    assert a == b


def test_spec_matches_plain_quantized_base():
    """The int8 base drafts for itself: a quantized AdapterStore base
    (deq fused at use sites) keeps bit-parity, zero extra weight bytes."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(
        quantize_tree(build_model(cfg).init(jax.random.PRNGKey(0))))
    store.put("u", _records(4, seed=3))
    plens, G = (5, 8), 5
    a, _, _ = _run(cfg, store, plens, G, users=["u", None])
    b, eng, _ = _run(cfg, store, plens, G, spec_k=3, users=["u", None])
    assert a == b
    assert eng.stats.spec_drafted > 0


def test_spec_small_delta_high_acceptance():
    """A near-zero delta makes draft ~= target: acceptance must be
    (near-)total, and the round count collapses accordingly."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("tiny", _records(2, seed=4, lr=1e-6))
    plens, G = (5, 7), 8
    a, _, _ = _run(cfg, store, plens, G, users=["tiny", "tiny"])
    b, eng, _ = _run(cfg, store, plens, G, spec_k=3,
                     users=["tiny", "tiny"])
    assert a == b
    assert eng.stats.spec_accept_rate > 0.9
    assert eng.stats.decode_steps < eng.stats.decode_tokens / 2


# ---------------------------------------------------------------------------
# flag validation


def test_spec_flag_validation():
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="spec_k must be >= 1"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=True, spec_k=0)
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=False,
                    spec_k=3)


def test_spec_rejected_without_pageable_state():
    """rwkv6 degrades paged=True to the dense layout -- there are no
    pages for the draft and verifier to share, so spec_k must be a loud
    constructor error, not a silent fallback."""
    cfg = get_config("rwkv6-7b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="no pageable state"):
        ServeEngine(cfg, store, n_slots=2, max_len=16, paged=True,
                    spec_k=2)


# ---------------------------------------------------------------------------
# sampled slots


def test_spec_sampled_slots_complete_and_reproduce():
    """Sampled slots run speculative rejection sampling: all requests
    complete at full length, the same engine seed reproduces the same
    tokens, and a different seed diverges."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    plens, G = (5, 7), 6
    kw = dict(greedy=False, topk=8, temperature=1.3)
    s1, eng, comps = _run(cfg, store, plens, G, spec_k=3, **kw)
    s2, _, _ = _run(cfg, store, plens, G, spec_k=3, **kw)
    s3, _, _ = _run(cfg, store, plens, G, spec_k=3, seed=7, **kw)
    assert all(len(o) == G for o in s1)
    assert s1 == s2
    assert s1 != s3
    assert eng.stats.spec_drafted > 0
    assert all(c.accept_rate is not None for c in comps)


def test_spec_mixed_greedy_and_sampled():
    """Greedy and sampled slots share one speculative round; the greedy
    slots' tokens still match the plain engine exactly."""
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    plens, G = (5, 9, 7), 6
    a, _, _ = _run(cfg, store, plens, G)                 # all greedy
    eng = ServeEngine(cfg, store, n_slots=3, max_len=max(plens) + G,
                      seed=0, paged=True, page_size=4, spec_k=3)
    prompts = _prompts(cfg, plens)
    r0 = eng.submit(Request(prompt=prompts[0], max_new=G))
    eng.submit(Request(prompt=prompts[1], max_new=G, greedy=False, topk=8))
    r2 = eng.submit(Request(prompt=prompts[2], max_new=G))
    comps = {c.rid: c for c in eng.run()}
    assert comps[r0].tokens.tolist() == a[0]
    assert comps[r2].tokens.tolist() == a[2]


def test_plain_engine_reports_no_accept_rate():
    cfg = get_config("gemma-2b").reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    _, eng, comps = _run(cfg, store, (5, 7), 4)
    assert eng.stats.spec_drafted == 0
    assert eng.stats.spec_accept_rate == 0.0
    assert all(c.accept_rate is None for c in comps)


# ---------------------------------------------------------------------------
# composition with chunked prefill


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_composes_with_chunked_prefill(arch):
    """spec_k + prefill_chunk together: chunked admission must coexist
    with speculative rounds (the in-flight prefill slot's lane is masked
    out of draft writes, verify, and commit), staying bit-identical to
    the plain paged engine."""
    cfg = get_config(arch).reduced()
    store = AdapterStore(build_model(cfg).init(jax.random.PRNGKey(0)))
    store.put("u", _records(4, seed=1))
    users = ["u", None, "u", None]
    plens, G = (5, 9, 7, 12), 6
    a, _, _ = _run(cfg, store, plens, G, users=users)
    eng = ServeEngine(cfg, store, n_slots=2, max_len=max(plens) + G,
                      seed=0, paged=True, page_size=4, spec_k=3,
                      prefill_chunk=3)
    rids = [eng.submit(Request(prompt=pr, max_new=G, user=users[i]))
            for i, pr in enumerate(_prompts(cfg, plens))]
    comps = {c.rid: c for c in eng.run()}
    assert [comps[r].tokens.tolist() for r in rids] == a
    assert eng.stats.spec_drafted > 0
