"""Mamba (selective SSM) block -- the recurrent sublayer of the hybrid
family. State is (conv window, ssm accumulator); prefill rolls both to
the last token with one full-sequence scan."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import mamba as M
from repro.models.blocks.base import BlockType, register_block


def _apply(cfg, p, x, rc, ctx=None):
    return M.mamba_apply(cfg, p, x, ctx=ctx), jnp.float32(0.0)


def _state_spec(cfg, bsz, max_len, dtype):
    di = cfg.mamba_expand * cfg.d_model
    return {"conv": ((bsz, cfg.mamba_d_conv - 1, di), dtype),
            "ssm": ((bsz, di, cfg.mamba_d_state), jnp.float32)}


def _decode_step(cfg, p, state, x, rc, ctx=None):
    return M.mamba_step(cfg, p, state, x)


def _prefill(cfg, p, state, x, rc, ctx=None):
    return M.mamba_prefill(cfg, p, state, x)


MAMBA = register_block(BlockType(
    name="mamba", init=M.mamba_init, apply=_apply,
    state_spec=_state_spec, prefill=_prefill, decode_step=_decode_step))
