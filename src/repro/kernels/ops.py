"""jit'd wrappers around the ZO Pallas kernels.

On non-TPU backends (this container) the kernels run in interpret mode,
which executes the kernel body in Python for correctness validation; on
TPU they compile to Mosaic.

Both wrappers accept ``scale=`` (per-output-channel f32 vector) to mark
``w`` as an int8 quantized base: dequantization then fuses into the same
kernel tile pass (see kernels/zo_perturb.py).
"""

from __future__ import annotations

import jax

from repro.kernels import zo_perturb as _k

_INTERPRET = jax.default_backend() != "tpu"


def paged_decode_attn(q, k_pages, v_pages, pages, pos):
    """Single-token attention over a paged KV pool: the Pallas
    flash-decoding kernel on TPU, the jnp gather reference elsewhere
    (decode is a hot loop -- interpret mode's per-grid-step Python body
    would dominate it; the reference is the same math as one XLA graph).
    """
    from repro.kernels import flash_decode as _fd
    if _INTERPRET:
        return _fd.paged_attn_ref(q, k_pages, v_pages, pages, pos)
    return _fd.flash_decode(q, k_pages, v_pages, pages, pos)


def paged_verify_attn(q, k_pages, v_pages, pages, pos):
    """Window attention over a paged KV pool for speculative verify:
    q is (B, W, H, hd) -- W candidate tokens per slot, offset w reading
    positions <= pos + w. Pallas flash-verify kernel on TPU, the jnp
    gather reference elsewhere (same hot-loop rationale as
    :func:`paged_decode_attn`)."""
    from repro.kernels import flash_verify as _fv
    if _INTERPRET:
        return _fv.verify_attn_ref(q, k_pages, v_pages, pages, pos)
    return _fv.flash_verify(q, k_pages, v_pages, pages, pos)


def paged_prefill_attn(q, k_pages, v_pages, pages, pos):
    """Chunk attention over a paged KV pool for chunked prefill: q is
    (B, C, H, hd) -- C prompt tokens per slot, offset c reading
    positions <= pos + c. Pallas flash-prefill kernel on TPU (whole
    chunk resident per page sweep), the jnp gather reference elsewhere
    (same hot-loop rationale as :func:`paged_decode_attn`)."""
    from repro.kernels import flash_prefill as _fp
    if _INTERPRET:
        return _fp.prefill_attn_ref(q, k_pages, v_pages, pages, pos)
    return _fp.flash_prefill(q, k_pages, v_pages, pages, pos)


def zo_add(w, seed, salt: int, coeff, dist: str = "rademacher",
           block=(256, 256), prime_offset: int = 0, prehashed: bool = False,
           scale=None):
    return _k.zo_add(w, seed, salt, coeff, dist=dist, block=block,
                     interpret=_INTERPRET, prime_offset=prime_offset,
                     prehashed=prehashed, scale=scale)


def zo_matmul(x, w, seed, salt: int, coeff, dist: str = "rademacher",
              blocks=(128, 128, 128), prime_offset: int = 0,
              prehashed: bool = False, scale=None):
    return _k.zo_matmul(x, w, seed, salt, coeff, dist=dist, blocks=blocks,
                        interpret=_INTERPRET, prime_offset=prime_offset,
                        prehashed=prehashed, scale=scale)


def zo_add_users(w, seeds, salt: int, coeffs, dist: str = "rademacher",
                 block=(256, 256), prime_offset: int = 0,
                 prehashed: bool = False):
    """Per-user stacked leaves: ``out[u] = w[u] + coeffs[u]*z(seeds[u])``."""
    return _k.zo_add_users(w, seeds, salt, coeffs, dist=dist, block=block,
                           interpret=_INTERPRET, prime_offset=prime_offset,
                           prehashed=prehashed)


def zo_matmul_users(x, w, seeds, salt: int, coeffs,
                    dist: str = "rademacher", blocks=(128, 128, 128),
                    prime_offset: int = 0, prehashed: bool = False,
                    scale=None):
    """B users' perturbed forwards against ONE resident (K, N) base:
    ``y[u] = x[u] @ (w + coeffs[u]*z(seeds[u]))`` in one dispatch."""
    return _k.zo_matmul_users(x, w, seeds, salt, coeffs, dist=dist,
                              blocks=blocks, interpret=_INTERPRET,
                              prime_offset=prime_offset,
                              prehashed=prehashed, scale=scale)
