"""HLO collective parser + roofline term arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import active_params, roofline_terms, total_params
from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.configs import get_config

FAKE_HLO = """
HloModule test

ENTRY %main (x: bf16[16,8,256]) -> u32[10] {
  %ag = bf16[16,128,256]{2,1,0} all-gather(%x), dimensions={1}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
  %ars = f32[64,32]{1,0} all-reduce-start(%z), to_apply=%add
  %ard = f32[64,32]{1,0} all-reduce-done(%ars)
  %rs = bf16[8,256]{1,0} reduce-scatter(%w), dimensions={0}
  %a2a = f32[4,16]{1,0} all-to-all(%v), dimensions={0}
  ROOT %cp = u32[10]{0} collective-permute(%u)
}
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(FAKE_HLO)
    kinds = [k for k, _ in ops]
    assert kinds.count("all-reduce") == 2        # plain + start, not done
    assert "all-gather" in kinds and "reduce-scatter" in kinds
    assert "all-to-all" in kinds and "collective-permute" in kinds
    sizes = dict()
    for k, b in ops:
        sizes.setdefault(k, 0)
        sizes[k] += b
    assert sizes["all-gather"] == 16 * 128 * 256 * 2
    assert sizes["all-reduce"] == 1024 * 4 + 64 * 32 * 4
    assert sizes["collective-permute"] == 10 * 4


def test_collective_bytes_ar_doubling():
    s = collective_bytes(FAKE_HLO)
    ar = 1024 * 4 + 64 * 32 * 4
    assert s["total"] == (2 * ar + s["all-gather"] + s["reduce-scatter"]
                          + s["all-to-all"] + s["collective-permute"])


def test_parser_on_real_lowered_psum():
    import os
    if jax.device_count() < 2:
        # single-device CI: lower with 1-device mesh still has no collective
        pytest.skip("needs >1 device to emit collectives")


def test_roofline_terms_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    t = roofline_terms(cost, None, n_chips=256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(2.0)
    assert t["bottleneck"] == "memory"


def test_param_counts_sane():
    # kimi ~1T total, ~32B active
    cfg = get_config("kimi-k2-1t-a32b")
    tot, act = total_params(cfg), active_params(cfg)
    assert 0.7e12 < tot < 1.4e12, tot
    assert 15e9 < act < 45e9, act
    # dense arch: total == active
    q = get_config("qwen3-4b")
    assert total_params(q) == active_params(q)
    assert 3e9 < total_params(q) < 7e9
    # granite ~1.3B total / ~0.4B active
    g = get_config("granite-moe-1b-a400m")
    assert 0.9e9 < total_params(g) < 1.8e9
    assert 0.2e9 < active_params(g) < 0.6e9


LOOPED_HLO = """
HloModule looped

%body (p: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
  %p = (s32[], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot = f32[4,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,16]{1,0} all-reduce(%dot), to_apply=%add
  ROOT %t = (s32[], f32[4,16]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[4,16])) -> pred[] {
  %p = (s32[], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,16]) -> f32[4,16] {
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[4,16]) tuple(%c0, %x)
  %w = (s32[], f32[4,16]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_loop_aware_analyzer_multiplies_trips():
    from repro.roofline.hlo import analyze
    a = analyze(LOOPED_HLO)
    # dot flops = 2*4*16*16 = 2048 per trip, x5 trips
    assert a["flops"] == 2048 * 5
    # AR result bytes 4*16*4 = 256 per trip x5, doubled for ring traffic
    assert a["collective_bytes"] == 256 * 5 * 2


def test_loop_aware_on_real_scan():
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo import analyze

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(jnp.dot(c, wi)), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((3, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((2, 8), jnp.float32)).compile()
    a = analyze(comp.as_text())
    assert a["flops"] == 2 * 2 * 8 * 8 * 3   # 3 trips
