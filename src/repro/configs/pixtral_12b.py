"""pixtral-12b [vlm]: pixtral-ViT (stub frontend) + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

The modality frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (batch, num_patches, d_model)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
        act="swiglu", norm="rmsnorm", pos="rope", rope_theta=1e6,
        num_patches=256, max_seq=32768)
