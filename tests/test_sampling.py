"""serve/sampling edge cases: the temperature->0 limit collapses to
greedy, k=1 is argmax regardless of key, seeded draws are reproducible,
and spec_accept's rejection sampling behaves at its limits."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import sampling


def _logits(b=4, v=64, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(b, v)).astype(np.float32))


def _keys(n, seed=0):
    _, ks = sampling.step_keys(jax.random.PRNGKey(seed), n)
    return ks


def test_topk_temperature_limit_is_greedy():
    """As temperature -> 0 the top-k softmax collapses onto the argmax:
    sample_topk must agree with greedy for every slot and any key."""
    lg = _logits()
    want = np.asarray(sampling.greedy(lg))
    for t in (1e-4, 1e-6, 0.0):           # 0 exercises the clamp
        got = np.asarray(sampling.sample_topk(_keys(4), lg, 8, t))
        np.testing.assert_array_equal(got, want)


def test_topk_k1_is_argmax():
    """k=1 renormalizes over a single candidate: the argmax, whatever
    the key or temperature."""
    lg = _logits(seed=1)
    for t in (0.3, 1.0, 2.5):
        got = np.asarray(sampling.sample_topk(_keys(4, seed=3), lg, 1, t))
        np.testing.assert_array_equal(got, np.asarray(sampling.greedy(lg)))


def test_topk_restricted_to_top_k():
    """Every sampled token must come from the k largest logits."""
    lg = _logits(b=8, seed=2)
    topk = np.argsort(np.asarray(lg), axis=1)[:, -4:]
    for seed in range(3):
        got = np.asarray(sampling.sample_topk(_keys(8, seed), lg, 4, 1.5))
        assert all(got[i] in topk[i] for i in range(8))


def test_step_keys_reproducible_and_distinct():
    k1, s1 = sampling.step_keys(jax.random.PRNGKey(0), 4)
    k2, s2 = sampling.step_keys(jax.random.PRNGKey(0), 4)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    assert len({tuple(np.asarray(k)) for k in s1}) == 4   # per-slot streams
    k3, _ = sampling.step_keys(k1, 4)
    assert tuple(np.asarray(k3)) != tuple(np.asarray(k1))  # key advances


# ---------------------------------------------------------------------------
# spec_accept (speculative rejection sampling against a greedy draft)


def test_spec_accept_deterministic():
    key = jax.random.PRNGKey(0)
    lg = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 32)).astype(np.float32))
    draft = jnp.asarray(np.array([3, 5, 9], np.int32))
    a = sampling.spec_accept(key, draft, lg, 8, 1.0)
    b = sampling.spec_accept(key, draft, lg, 8, 1.0)
    assert (int(a[0]), int(a[1])) == (int(b[0]), int(b[1]))


def test_spec_accept_greedy_limit_full_accept():
    """temperature -> 0 makes the target one-hot at its argmax; a draft
    that IS the argmax chain must be fully accepted and the bonus token
    must be the final position's argmax -- the greedy spec path."""
    lg = jnp.asarray(np.random.default_rng(1).normal(
        size=(4, 32)).astype(np.float32))
    draft = jnp.argmax(lg[:3], axis=1).astype(jnp.int32)
    for seed in range(5):
        n, nxt = sampling.spec_accept(jax.random.PRNGKey(seed), draft,
                                      lg, 8, 1e-9)
        assert int(n) == 3
        assert int(nxt) == int(jnp.argmax(lg[3]))


def test_spec_accept_greedy_limit_rejects_wrong_draft():
    """In the same limit a draft token off the argmax is rejected at its
    position and the resample emits the target argmax (the correction
    token of greedy speculative decoding)."""
    lg = jnp.asarray(np.random.default_rng(2).normal(
        size=(3, 32)).astype(np.float32))
    am = np.asarray(jnp.argmax(lg, axis=1))
    draft = jnp.asarray(np.array([am[0], (am[1] + 1) % 32], np.int32))
    for seed in range(5):
        n, nxt = sampling.spec_accept(jax.random.PRNGKey(seed), draft,
                                      lg, 8, 1e-9)
        assert int(n) == 1                 # position 0 right, 1 wrong
        assert int(nxt) == am[1]           # correction = target argmax


def test_spec_accept_token_in_topk():
    """Whatever is emitted (accepted, correction, or bonus) must lie in
    the target's top-k support at its position."""
    lg = jnp.asarray(np.random.default_rng(3).normal(
        size=(4, 64)).astype(np.float32))
    topk = np.argsort(np.asarray(lg), axis=1)[:, -8:]
    draft = jnp.asarray(np.array([1, 2, 3], np.int32))
    for seed in range(10):
        n, nxt = sampling.spec_accept(jax.random.PRNGKey(seed), draft,
                                      lg, 8, 1.0)
        n = int(n)
        assert 0 <= n <= 3
        assert int(nxt) in topk[n]


def test_spec_accept_residual_excludes_rejected_token():
    """On rejection the residual zeroes the draft token: a rejected
    token can never be re-emitted at the same position (p - q clamps
    its mass to zero)."""
    v = 16
    lg = np.full((2, v), -10.0, np.float32)
    lg[0, :4] = [2.0, 1.9, 1.8, 1.7]      # draft token has p < 1
    lg[1, 0] = 5.0
    draft = jnp.asarray(np.array([1], np.int32))   # in support, not argmax
    seen_reject = False
    for seed in range(40):
        n, nxt = sampling.spec_accept(jax.random.PRNGKey(seed), draft,
                                      jnp.asarray(lg), 4, 1.0)
        if int(n) == 0:                    # rejected at position 0
            seen_reject = True
            assert int(nxt) != 1
    assert seen_reject                     # p(draft) ~ 0.3: must reject
