"""Sharded on-disk parameter store (npz per leaf-group + json manifest).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts
the latest checkpoint -- the fault-tolerance contract the runtime relies
on. Multi-host note: each process saves only addressable shards; here
(single process) that is the whole tree.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(params: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_params(ckpt_dir: str, step: int, params: PyTree,
                extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    arrays, _ = _flatten(params)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in arrays.items()})
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_params(ckpt_dir: str, step: int, like: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like`` (values replaced)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), sh in zip(leaves, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_manifest(ckpt_dir: str, step: int) -> dict:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)
