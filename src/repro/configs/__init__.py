"""Architecture registry: 10 assigned archs + the paper's own two models.

``get_config(arch_id)`` -> ModelConfig; ``ARCHS`` lists assigned ids.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "qwen3-4b", "glm4-9b", "chatglm3-6b", "gemma-2b", "pixtral-12b",
    "jamba-v0.1-52b", "kimi-k2-1t-a32b", "granite-moe-1b-a400m",
    "rwkv6-7b", "whisper-base",
]
PAPER_ARCHS = ["roberta-large", "opt-1.3b"]
ALL_ARCHS = ARCHS + PAPER_ARCHS

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ALL_ARCHS}


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).config()
