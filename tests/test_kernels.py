"""Per-kernel shape/dtype sweeps asserting allclose vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

ADD_SHAPES = [(8, 128), (128, 128), (256, 512), (384, 640), (100, 300)]
MM_SHAPES = [(8, 128, 128), (128, 256, 128), (64, 384, 256), (32, 100, 60)]


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", ADD_SHAPES)
def test_zo_add_sweep(shape, dtype, dist):
    w = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    got = ops.zo_add(w, 42, 777, 0.125, dist=dist)
    want = ref.zo_add_ref(w, jnp.uint32(42), 777, 0.125, dist=dist)
    assert got.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_zo_matmul_sweep(mkn, dtype, dist):
    m, k, n = mkn
    x = (jax.random.normal(KEY, (m, k), jnp.float32) * 0.1).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), jnp.float32)
         * 0.1).astype(dtype)
    got = ops.zo_matmul(x, w, 7, 123, 0.01, dist=dist)
    want = ref.zo_matmul_ref(x, w, jnp.uint32(7), 123, 0.01, dist=dist)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_zo_add_block_invariance():
    """Result must not depend on the BlockSpec tiling."""
    w = jax.random.normal(KEY, (256, 256), jnp.float32)
    a = ops.zo_add(w, 3, 9, 1.0, block=(256, 256))
    b = ops.zo_add(w, 3, 9, 1.0, block=(64, 128))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zo_matmul_block_invariance():
    x = jax.random.normal(KEY, (128, 256), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 128),
                          jnp.float32) * 0.1
    a = ops.zo_matmul(x, w, 5, 6, 0.5, blocks=(128, 256, 128))
    b = ops.zo_matmul(x, w, 5, 6, 0.5, blocks=(64, 64, 64))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_zero_coeff_is_identity_matmul():
    x = jax.random.normal(KEY, (64, 128), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (128, 64), jnp.float32)
    got = ops.zo_matmul(x, w, 0, 0, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


# ---- user-batched variants (multi-tenant TrainEngine hot path) ------------

U_SEEDS = jnp.asarray([42, 7, 1000, 3], jnp.uint32)
U_COEFFS = jnp.asarray([0.125, -0.5, 0.01, 0.0], jnp.float32)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_zo_matmul_users_bit_equals_scalar_loop(dist):
    """One user-batched dispatch == U lone zo_matmul calls, bit-exact
    (same block shapes => same per-lane accumulation order)."""
    u, m, k, n = len(U_SEEDS), 64, 128, 128
    x = jax.random.normal(KEY, (u, m, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 4), (k, n),
                          jnp.float32) * 0.1
    got = ops.zo_matmul_users(x, w, U_SEEDS, 123, U_COEFFS, dist=dist)
    assert got.shape == (u, m, n)
    for i in range(u):
        want = ops.zo_matmul(x[i], w, U_SEEDS[i], 123, U_COEFFS[i],
                             dist=dist)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want),
                                      err_msg=f"user lane {i}")


def test_zo_matmul_users_int8_scale_equals_scalar_loop():
    """The quantized variant (shared int8 base + per-channel scales).

    The dequant expression ``w*scale + coeff*z`` has two multiplies, and
    XLA may contract the mul+add pair differently across the two
    (otherwise textually identical) kernels, so this path is pinned to
    one-ulp agreement rather than atol=0; the single-multiply f32 path
    above stays bit-exact.
    """
    u, m, k, n = len(U_SEEDS), 32, 128, 128
    x = jax.random.normal(KEY, (u, m, k), jnp.float32) * 0.1
    q = jax.random.randint(jax.random.fold_in(KEY, 5), (k, n), -127, 128,
                           jnp.int8)
    scale = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 6), (n,),
                                      jnp.float32)) * 0.01 + 1e-4
    got = ops.zo_matmul_users(x, q, U_SEEDS, 9, U_COEFFS, scale=scale)
    for i in range(u):
        want = ops.zo_matmul(x[i], q, U_SEEDS[i], 9, U_COEFFS[i],
                             scale=scale)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"user lane {i}")


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
def test_zo_add_users_bit_equals_scalar_loop(dist):
    u, m, n = len(U_SEEDS), 128, 256
    w = jax.random.normal(jax.random.fold_in(KEY, 7), (u, m, n), jnp.float32)
    got = ops.zo_add_users(w, U_SEEDS, 77, U_COEFFS, dist=dist)
    for i in range(u):
        want = ops.zo_add(w[i], U_SEEDS[i], 77, U_COEFFS[i], dist=dist)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want),
                                      err_msg=f"user lane {i}")


def test_zo_matmul_users_prehashed_matches_raw():
    """The ctx hot path passes prehashed per-(user, leaf) bases; they
    must draw the same streams as the raw (seed, salt) form."""
    from repro.core import rng as zrng
    u, m, k, n = len(U_SEEDS), 32, 128, 128
    x = jax.random.normal(KEY, (u, m, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 8), (k, n),
                          jnp.float32) * 0.1
    raw = ops.zo_matmul_users(x, w, U_SEEDS, 55, U_COEFFS)
    base = zrng.leaf_base(U_SEEDS, 55)
    pre = ops.zo_matmul_users(x, w, base, 0, U_COEFFS, prehashed=True)
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(pre))
