"""User-axis batching helpers shared by the serve and train engines.

Both engines run a fixed slot table over one resident base model and
advance many tenants per dispatch; what varies is only *where* the slot
axis lives (the TrainEngine stacks per-user state on axis 0, the
ServeEngine's unified StateCache batches sequences on axis 1). This
module is the single copy of the slot-axis plumbing:

* :func:`masked_merge` — the ragged-slot merge both engines use: keep a
  slot's previous value wherever its mask bit is off (mid-flight
  admission, early finishers, per-adapter decode dispatch);
* :func:`user_leaf_axes` / :func:`user_state_axes` — ``vmap`` axes trees
  for user-stacked params / TrainState where every per-user leaf maps to
  axis 0 but quantized leaves keep the single resident int8 base
  (``q`` / ``scale`` -> ``None``: shared, never copied per user);
* :func:`stack_users` / :func:`install_user` / :func:`take_user` — build
  a user-stacked pytree from per-user trees, scatter one user into a
  slot lane, and read one lane back out.

The quantized-leaf convention throughout: ``q``/``scale`` are frozen and
shared across all users (PR 5's single resident int8 base), only the f32
``delta`` carries per-user state — so U tenants cost one int8 base plus
U delta sets, and a delta-less (frozen) leaf has no per-user axis at all.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.optim.quant import QuantizedLeaf, is_quantized

PyTree = Any


def masked_merge(old: PyTree, new: PyTree, mask, axis: int = 0) -> PyTree:
    """Per-slot select: ``new`` where ``mask``, ``old`` elsewhere.

    ``mask`` is a (n_slots,) boolean vector; ``axis`` is the slot axis of
    every leaf (0 for user-stacked train state, 1 for the serve engines'
    unified StateCache). Quantized leaves merge only their per-user f32
    ``delta`` — the int8 base is shared, so there is nothing to mask —
    and frozen (delta-less) leaves pass through whole.
    """
    mask = jnp.asarray(mask, bool)

    def pick(o, n):
        if is_quantized(o):
            if o.delta is None:
                return n
            return dataclasses.replace(n, delta=pick(o.delta, n.delta))
        m = jnp.reshape(mask, (1,) * axis + (-1,)
                        + (1,) * (o.ndim - axis - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(pick, old, new, is_leaf=is_quantized)


# ---------------------------------------------------------------------------
# vmap axes trees (user axis 0; quantized base shared)


def user_leaf_axes(params: PyTree) -> PyTree:
    """vmap in/out axes for a user-stacked params tree: plain leaves map
    over axis 0; quantized leaves map only their ``delta`` (``q`` and
    ``scale`` stay ``None`` — ONE resident int8 base serves every lane)."""
    def ax(leaf):
        if is_quantized(leaf):
            return QuantizedLeaf(q=None, scale=None,
                                 delta=None if leaf.delta is None else 0,
                                 orig_dtype=leaf.orig_dtype)
        return 0
    return jax.tree.map(ax, params, is_leaf=is_quantized)


def user_state_axes(state) -> Any:
    """Axes tree for a user-stacked ``TrainState`` (params per
    :func:`user_leaf_axes`; step counter and opt state fully stacked)."""
    from repro.core.engine import TrainState
    return TrainState(params=user_leaf_axes(state.params), step=0,
                      opt=jax.tree.map(lambda _: 0, state.opt))


class AxesSpec:
    """Hashable wrapper around an axes pytree, so a jitted function can
    take it as a static argument (pytrees of dicts aren't hashable)."""

    __slots__ = ("_leaves", "_treedef")

    def __init__(self, axes_tree: PyTree):
        leaves, treedef = jax.tree_util.tree_flatten(axes_tree)
        self._leaves = tuple(leaves)
        self._treedef = treedef

    def unflatten(self) -> PyTree:
        return jax.tree_util.tree_unflatten(self._treedef, self._leaves)

    def __eq__(self, other) -> bool:
        return (isinstance(other, AxesSpec)
                and self._leaves == other._leaves
                and self._treedef == other._treedef)

    def __hash__(self) -> int:
        return hash((self._leaves, self._treedef))


# ---------------------------------------------------------------------------
# slot-lane scatter/gather


def stack_users(trees: Sequence[PyTree]) -> PyTree:
    """Stack per-user pytrees on a new leading user axis. Quantized
    leaves keep the first tree's int8 base (all users share it by
    construction) and stack only the f32 deltas."""
    def st(*leaves):
        first = leaves[0]
        if is_quantized(first):
            if first.delta is None:
                return first
            return dataclasses.replace(
                first, delta=jnp.stack([l.delta for l in leaves]))
        return jnp.stack([jnp.asarray(l) for l in leaves])
    return jax.tree.map(st, *trees, is_leaf=is_quantized)


@jax.jit
def _install(stacked: PyTree, tree: PyTree, slot) -> PyTree:
    def put(s, t):
        if is_quantized(s):
            if s.delta is None:
                return s
            return dataclasses.replace(
                s, delta=s.delta.at[slot].set(t.delta))
        return s.at[slot].set(jnp.asarray(t, s.dtype))
    return jax.tree.map(put, stacked, tree, is_leaf=is_quantized)


def install_user(stacked: PyTree, tree: PyTree, slot: int) -> PyTree:
    """Scatter one user's (unstacked) pytree into slot lane ``slot``.
    The slot index is traced, so admissions into different slots reuse
    one compiled scatter."""
    return _install(stacked, tree, jnp.asarray(slot, jnp.int32))


def take_user(stacked: PyTree, slot: int) -> PyTree:
    """Read one slot lane back out as an unstacked per-user pytree."""
    def tk(s):
        if is_quantized(s):
            if s.delta is None:
                return s
            return dataclasses.replace(s, delta=s.delta[slot])
        return s[slot]
    return jax.tree.map(tk, stacked, is_leaf=is_quantized)
