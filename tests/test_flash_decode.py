"""Flash-decoding kernel: parity against the gather reference and a
dense attention oracle, across GQA layouts, ragged per-slot positions
(including page-boundary straddlers), and scrambled page tables.

The Pallas kernel runs in interpret mode here (CI is CPU); the serving
hot path routes through :func:`paged_attn_ref` off-TPU, so both
implementations are pinned against the same dense oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import (MXU_HEAD_DIMS, flash_decode,
                                        paged_attn_ref)
from repro.models.layers import attention

PS = 8  # page size


def _paged_case(seed, b, h, kvh, hd, n_live, pos):
    """Random q + page pools with a *scrambled* page table: each slot's
    logical pages map to arbitrary distinct physical pages (page 0 kept
    as the trash page), dead-tail table entries point at trash."""
    rng = np.random.default_rng(seed)
    n_pages = 1 + b * n_live + 3          # trash + slots' pages + spares
    q = rng.normal(size=(b, h, hd)).astype(np.float32)
    k = rng.normal(size=(n_pages, PS, kvh, hd)).astype(np.float32)
    v = rng.normal(size=(n_pages, PS, kvh, hd)).astype(np.float32)
    pos = np.asarray(pos, np.int32)
    perm = rng.permutation(np.arange(1, n_pages))   # never hand out trash
    pages = np.zeros((b, n_live), np.int32)
    for i in range(b):
        live = 1 + pos[i] // PS
        pages[i, :live] = perm[i * n_live:i * n_live + live]
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(pages), jnp.asarray(pos))


def _dense_oracle(q, k_pages, v_pages, pages, pos):
    """Gather pages to contiguous (B, S, KV, hd) and run plain masked
    attention -- the layout-free ground truth."""
    b, h, hd = q.shape
    kk = np.asarray(k_pages)[np.asarray(pages)].reshape(b, -1, *k_pages.shape[2:])
    vv = np.asarray(v_pages)[np.asarray(pages)].reshape(b, -1, *v_pages.shape[2:])
    valid = np.arange(kk.shape[1])[None] <= np.asarray(pos)[:, None]
    out = attention(q[:, None], jnp.asarray(kk), jnp.asarray(vv),
                    causal=False, kv_mask=jnp.asarray(valid), chunk=0)
    return np.asarray(out[:, 0])


# boundary-straddling per-slot positions: last row of a page, first row
# of the next, mid-page, and a slot whose live range is a single token
RAGGED_POS = (PS - 1, PS, 2 * PS + 3, 0)


@pytest.mark.parametrize("kvh,g", [(1, 4), (2, 2), (4, 1)])
def test_kernel_matches_dense_oracle_gqa(kvh, g):
    q, k, v, pages, pos = _paged_case(0, b=4, h=kvh * g, kvh=kvh, hd=16,
                                      n_live=4, pos=RAGGED_POS)
    want = _dense_oracle(q, k, v, pages, pos)
    got_ref = np.asarray(paged_attn_ref(q, k, v, pages, pos))
    got_kern = np.asarray(flash_decode(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(got_ref, want, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(got_kern, want, rtol=2e-4, atol=2e-5)


def test_kernel_ignores_trash_page_contents():
    """Dead table entries point at physical page 0; whatever is in it
    must not leak into any slot's output."""
    q, k, v, pages, pos = _paged_case(1, b=3, h=4, kvh=2, hd=16,
                                      n_live=4, pos=(3, PS, 2 * PS - 1))
    poisoned_k = k.at[0].set(1e4)
    poisoned_v = v.at[0].set(1e4)
    a = np.asarray(flash_decode(q, k, v, pages, pos, interpret=True))
    bb = np.asarray(flash_decode(q, poisoned_k, poisoned_v, pages, pos,
                                 interpret=True))
    np.testing.assert_allclose(a, bb, rtol=1e-6)
    r = np.asarray(paged_attn_ref(q, poisoned_k, poisoned_v, pages, pos))
    np.testing.assert_allclose(a, r, rtol=2e-4, atol=2e-5)


def test_single_live_page():
    """n_live == 1: the init / accumulate / finalize grid steps coincide."""
    q, k, v, pages, pos = _paged_case(2, b=2, h=2, kvh=1, hd=16,
                                      n_live=1, pos=(0, PS - 1))
    want = _dense_oracle(q, k, v, pages, pos)
    got = np.asarray(flash_decode(q, k, v, pages, pos, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_head_dim_validation():
    """Off-MXU head dims must be a loud ValueError when compiling for
    real hardware (interpret mode lifts it for CI correctness runs)."""
    q, k, v, pages, pos = _paged_case(3, b=2, h=2, kvh=1, hd=16,
                                      n_live=2, pos=(1, 2))
    with pytest.raises(ValueError, match="MXU"):
        flash_decode(q, k, v, pages, pos, interpret=False)
    for hd in MXU_HEAD_DIMS:  # aligned dims pass validation (trace only)
        jax.eval_shape(
            lambda qq, kk, vv: flash_decode(qq, kk, vv, pages, pos,
                                            interpret=True),
            jax.ShapeDtypeStruct((2, 2, hd), jnp.float32),
            jax.ShapeDtypeStruct(k.shape[:3] + (hd,), jnp.float32),
            jax.ShapeDtypeStruct(v.shape[:3] + (hd,), jnp.float32))


def test_flash_attention_head_dim_validation():
    from repro.kernels.flash_attention import flash_attention
    q = jnp.zeros((1, 4, 2, 24), jnp.float32)   # hd=24: not MXU-aligned
    with pytest.raises(ValueError, match="MXU"):
        flash_attention(q, q, q, interpret=False)
