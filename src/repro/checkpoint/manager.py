"""Checkpoint manager: snapshots + replay log + auto-resume.

Policy: full *train-state* snapshot every ``snapshot_every`` steps
(expensive, rare), replay-log append every step (cheap, always).
``restore()`` finds the newest snapshot, replays the log tail, and
reports the step to resume from -- giving per-step restart granularity at
snapshot-level IO cost.

What gets snapshotted is the engine's whole :class:`TrainState` pytree
(params, step counter, update-rule state), not bare params -- so momentum
history and Adam moments survive a crash instead of silently resetting.
Replay of the log tail goes through the strategy's *update rule*
(``rule.update_fn``), which consumes only the logged ``(seed, gs)``
scalars: sgd replay is the classic seed-replay sweep, momentum replay
additionally rolls the truncated history window forward, so the restored
state is step-for-step what the live run had.

For the Adam baseline (no replay log possible -- gradients depend on
data) it degrades to snapshot-only recovery, losing the steps since the
last snapshot: this asymmetry is measured in benchmarks/table1_memory.py.

Bare-params pytrees (no TrainState) are still accepted when the caller
passes one as ``restore(like=...)``; they replay through
``repro.core.mezo.replay_update`` as before. Note the snapshot *format*
follows the ``like`` structure: a directory written with bare params
cannot be restored as a TrainState (or vice versa) — the Trainer always
snapshots TrainStates.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.checkpoint.replay_log import ReplayLog, replay_into
from repro.core.engine import SGD, TrainState, UpdateRule

PyTree = Any


class CheckpointManager:
    def __init__(self, ckpt_dir: str, mezo_cfg=None,
                 snapshot_every: int = 100, keep: int = 2,
                 update_rule: Optional[UpdateRule] = None):
        self.dir = ckpt_dir
        self.cfg = mezo_cfg
        self.snapshot_every = snapshot_every
        self.keep = keep
        self.rule = update_rule
        self.log = (ReplayLog(os.path.join(ckpt_dir, "replay.jsonl"))
                    if mezo_cfg is not None else None)

    # ---- save -----------------------------------------------------------
    def on_step(self, step: int, state: PyTree, aux=None,
                direction_mask=None):
        """``state`` is the full TrainState (or a bare params pytree);
        ``direction_mask`` is the step's straggler mask, logged so replay
        renormalizes over the same survivors."""
        if self.log is not None and aux is not None:
            self.log.append(step, aux.seed, aux.gs, self.cfg.lr,
                            self.cfg.eps, mask=direction_mask)
        if step % self.snapshot_every == 0:
            store.save_params(self.dir, step, state)
            self._gc()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))

    # ---- restore --------------------------------------------------------
    def restore(self, like: PyTree, shardings=None
                ) -> Tuple[Optional[PyTree], int]:
        """Returns (state, next_step) or (None, 0) when nothing saved.

        ``like`` fixes the structure/shapes: a TrainState restores the
        full state (opt state included) and replays the log tail through
        the update rule; a bare params pytree keeps the legacy
        params-only behavior.
        """
        snap = store.latest_step(self.dir)
        if snap is None:
            return None, 0
        obj = store.load_params(self.dir, snap, like, shardings)
        if self.log is None:
            if isinstance(obj, TrainState):
                obj = dataclasses.replace(obj, step=jnp.uint32(snap + 1))
            return obj, snap + 1
        records = ReplayLog.read(os.path.join(self.dir, "replay.jsonl"),
                                 after_step=snap)
        if isinstance(obj, TrainState):
            state, last = self._replay_state(obj, records)
            nxt = max(snap, last) + 1
            return dataclasses.replace(state, step=jnp.uint32(nxt)), nxt
        params, last = replay_into(obj, records, self.cfg)
        return params, max(snap, last) + 1

    def _replay_state(self, state: TrainState, records
                      ) -> Tuple[TrainState, int]:
        """Replay logged (seed, gs) records through the update rule --
        zero forward passes; momentum history rolls forward exactly as
        the live steps would have rolled it."""
        rule = self.rule
        if rule is None:
            if jax.tree_util.tree_leaves(state.opt):
                raise ValueError(
                    "restoring a TrainState with non-empty update-rule "
                    "state requires the update_rule= the run was trained "
                    "with; silently replaying the log tail with sgd would "
                    "leave the optimizer state stale")
            rule = SGD
        params, opt, last = state.params, state.opt, -1
        for rec in records:
            c = dataclasses.replace(self.cfg, lr=rec["lr"], eps=rec["eps"])
            mask = rec.get("mask")
            params, opt = rule.update_fn(
                params, opt, np.uint32(rec["seed"]),
                np.asarray(rec["gs"], np.float32),
                None if mask is None else np.asarray(mask, np.float32), c)
            last = rec["step"]
        return dataclasses.replace(state, params=params, opt=opt), last
