"""Table 4 (multi-tenant training): batched TrainEngine vs sequential
per-user fine-tunes.

PocketLLM fine-tunes one user on one phone; a server aggregating many
users' ZO fine-tunes wants B of them per dispatch. This table measures
the user-steps/s of the batched TrainEngine (one vmapped fused step
advancing every resident slot) against B sequential Trainer-equivalent
runs of identical arithmetic -- the engine's outputs are bit-identical
per user (tests/test_train_engine.py), so the speedup is free.

The int8 arm also accounts the resident-memory story: U tenants share
ONE quantized base (q + scales); per-user state is only the f32 deltas.

Reduced-config CPU numbers (same caveat as tables 2/3: relative effects
are what transfer; on TPU the batched win grows with the MXU's appetite
for the user axis).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.core.engine import build_strategy
from repro.core import rng as zrng
from repro.models import build_model
from repro.optim.quant import is_quantized, quantize_tree
from repro.serve.adapters import AdapterStore, tree_bytes
from repro.train import TrainEngine, TrainJob, derive_user_seed

U, T, B, S = 8, 5, 1, 16      # users, steps/user, batch, seq


def _batches(cfg, user: str, seed: int = 0):
    salt = zrng.leaf_salt(f"{seed}/{user}")

    def fn(step: int):
        rng = np.random.default_rng((salt, step))
        toks = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "loss_mask": np.ones((B, S), np.float32)}
    return fn


def _delta_bytes(tree) -> int:
    """Per-user f32 delta bytes of a quantized tree (the only per-user
    state when the int8 base is shared)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_quantized):
        if is_quantized(leaf) and leaf.delta is not None:
            total += leaf.delta.nbytes
    return total


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    mz = MezoConfig(eps=1e-3, lr=1e-4, n_directions=1)
    strat = build_strategy("fused", "sgd")
    base_f32 = model.init(jax.random.PRNGKey(0))
    rows, table = [], {"users": U, "steps": T, "batch": B, "seq": S}

    for arm in ("f32", "int8"):
        base = (base_f32 if arm == "f32"
                else quantize_tree(base_f32, with_delta=True))

        # -- sequential: U independent runs, identical arithmetic ---------
        def seq_wave(wave: int):
            for u in range(U):
                user = f"w{wave}-{u}"
                st = strat.init_state(jax.tree.map(
                    lambda x: x.copy() if hasattr(x, "copy") else x,
                    jax.tree.map(jnp.asarray, base)), mz)
                fn = _batches(cfg, user)
                us = np.uint32(derive_user_seed(0, user))
                for t in range(T):
                    seed = zrng.fold_seed(jnp.uint32(us), t)
                    st, aux = strat.step(model.loss, st, fn(t), seed, mz)
                jax.block_until_ready(aux.loss)

        seq_wave(0)                                   # compile
        t0 = time.perf_counter()
        seq_wave(1)
        seq_s = time.perf_counter() - t0
        seq_ups = U * T / seq_s

        # -- batched engine: one wave warms the jit, the next is timed ----
        store = AdapterStore(jax.tree.map(jnp.asarray, base), mezo_cfg=mz)
        eng = TrainEngine(cfg, store, n_slots=U, seed=0)

        def eng_wave(wave: int):
            for u in range(U):
                user = f"w{wave}-{u}"
                eng.submit(TrainJob(user=user,
                                    batches=_batches(cfg, user), n_steps=T))
            eng.run()

        eng_wave(0)                                   # compile
        t0 = time.perf_counter()
        eng_wave(1)
        eng_s = time.perf_counter() - t0
        eng_ups = U * T / eng_s
        speedup = eng_ups / seq_ups

        rows.append((f"table4/{arm}_sequential", seq_s / (U * T) * 1e6,
                     f"{seq_ups:.2f} user-steps/s ({U} lone runs)"))
        rows.append((f"table4/{arm}_engine", eng_s / (U * T) * 1e6,
                     f"{eng_ups:.2f} user-steps/s ({speedup:.1f}x, "
                     f"{U} slots/dispatch)"))
        table[arm] = {"seq_user_steps_per_s": seq_ups,
                      "engine_user_steps_per_s": eng_ups,
                      "speedup": speedup}

        if arm == "int8":
            db = _delta_bytes(store.base)
            bb = tree_bytes(store.base) - db    # q + scales only
            rows.append(("table4/int8_resident_base", 0.0,
                         f"{bb / 1e6:.2f} MB shared + "
                         f"{db / 1e6:.2f} MB f32 delta/user"))
            table[arm].update({"base_bytes": bb,
                               "delta_bytes_per_user": db,
                               "f32_base_bytes": tree_bytes(base_f32)})

    with open(os.path.join(out_dir, "table4_multitenant.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
