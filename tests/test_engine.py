"""Composable ZO engine: estimator×update registry matrix, TrainState
checkpointing (momentum / Adam resume), straggler-mask renormalization
across all combinations, replay parity, loss buffering, chunked stepping."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import (MezoConfig, build_strategy, estimator_names,
                        fold_seed, get_strategy, mezo_step_vmapdir,
                        replay_update, spsa_gradient_estimate,
                        strategy_names, update_rule_names)
from repro.core.engine import TrainState
from repro.data.synthetic import lm_batches
from repro.optim.adam import AdamConfig
from repro.runtime import Trainer, TrainerConfig

ALL_COMBOS = [(e, u) for e in ("walk", "vmapdir", "fused")
              for u in ("sgd", "momentum")]

CFG = get_config("qwen3-4b").reduced()


def _batches(start=0):
    return lm_batches(4, 16, CFG.vocab, seed=3, start_step=start)


@pytest.fixture
def quad():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 8)), "b": jnp.zeros((8,))}
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = x @ (jnp.eye(8) * 0.1)

    def loss_fn(p, batch, perturb=None):
        # fused estimator support: materialize the ctx's z transiently --
        # bit-identical to add_scaled_z on this plain dict tree
        if perturb is not None:
            p = perturb.materialize(p)
        xx, yy = batch
        return jnp.mean((xx @ p["w"] + p["b"] - yy) ** 2)

    return params, (x, y), loss_fn


# ---------------------------------------------------------------------------
# registry


def test_registry_names_and_errors():
    assert set(estimator_names()) == {"walk", "vmapdir", "fused"}
    assert set(update_rule_names()) == {"sgd", "stale-sgd", "momentum"}
    for name in strategy_names():
        # cached singletons: jit caches keyed on the strategy stay warm
        assert get_strategy(name) is get_strategy(name)
    with pytest.raises(ValueError, match="mezo-fused"):
        get_strategy("sgdm")
    with pytest.raises(ValueError, match="vmapdir"):
        build_strategy("vmap", "sgd")
    with pytest.raises(ValueError, match="momentum"):
        build_strategy("walk", "adamw")


def test_unknown_trainer_optimizer_lists_strategies():
    with pytest.raises(ValueError) as ei:
        Trainer(CFG, TrainerConfig(optimizer="sgd"), iter(()))
    msg = str(ei.value)
    assert "mezo-parallel" in msg and "mezo-fused" in msg and "adam" in msg


def test_cli_flags_reach_strategy():
    from repro.launch.train import build_argparser, make_trainer
    args = build_argparser().parse_args(
        ["--arch", "opt-1.3b", "--reduced", "--estimator", "fused",
         "--update", "momentum", "--steps", "2", "--batch", "2",
         "--seq", "8"])
    assert make_trainer(args).strategy.name == "fused+momentum"
    args = build_argparser().parse_args(
        ["--arch", "opt-1.3b", "--reduced", "--optimizer", "mezo-momentum"])
    assert make_trainer(args).strategy.name == "vmapdir+momentum"


# ---------------------------------------------------------------------------
# the full 3×2 matrix: constructible, descends, matches the SPSA estimate


@pytest.mark.parametrize("est,upd", ALL_COMBOS)
def test_matrix_constructible_and_descends(quad, est, upd):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4, momentum=0.9,
                     momentum_window=4)
    strat = build_strategy(est, upd)
    assert strat.name == f"{est}+{upd}"
    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    losses = []
    for t in range(60):
        state, aux = strat.step(loss_fn, state, batch, jnp.uint32(t), cfg)
        losses.append(float(aux.loss))
    assert int(state.step) == 60
    assert losses[-1] < 0.9 * losses[0]


@pytest.mark.parametrize("est,upd", ALL_COMBOS)
def test_matrix_matches_spsa_estimate(quad, est, upd):
    """One step of every combination equals theta - lr * w * g_spsa where
    g_spsa is the materialized estimator cross-check (w = 1-beta for a
    fresh momentum window, 1 for sgd)."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4, momentum=0.9,
                     momentum_window=4)
    strat = build_strategy(est, upd)
    state, _ = strat.step(
        loss_fn, strat.init_state(jax.tree.map(jnp.copy, params), cfg),
        batch, jnp.uint32(3), cfg)
    g = spsa_gradient_estimate(loss_fn, params, batch, jnp.uint32(3), cfg)
    w = (1.0 - cfg.momentum) if upd == "momentum" else 1.0
    want = jax.tree.map(lambda p, gg: p - cfg.lr * w * gg, params, g)
    tol = (dict(rtol=1e-3, atol=1e-4) if est == "walk"     # walk drift
           else dict(rtol=1e-5, atol=1e-6))
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


def test_pristine_estimators_share_replay_log_bit_exact(quad):
    """vmapdir and fused produce the same (seed, gs) record, and
    replay_update reconstructs each one's params bit-for-bit."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=3)
    outs = {}
    for est in ("vmapdir", "fused"):
        strat = build_strategy(est, "sgd")
        state, aux = strat.step(
            loss_fn, strat.init_state(jax.tree.map(jnp.copy, params), cfg),
            batch, jnp.uint32(11), cfg)
        outs[est] = (state.params, aux)
    np.testing.assert_allclose(np.asarray(outs["vmapdir"][1].gs),
                               np.asarray(outs["fused"][1].gs),
                               rtol=1e-6, atol=1e-7)
    for est, (p_new, aux) in outs.items():
        p_rep = replay_update(jax.tree.map(jnp.copy, params), aux.seed,
                              aux.gs, cfg)
        for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(p_rep)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# straggler direction_mask: unbiased mean over survivors, all combinations


@pytest.mark.parametrize("est,upd", ALL_COMBOS)
def test_direction_mask_unbiased_over_survivors(quad, est, upd):
    """Masking directions 2,3 of a K=4 step must equal an unmasked K=2
    step (same folded seeds, renormalized mean) for every estimator ×
    update combination."""
    params, batch, loss_fn = quad
    mk = lambda k: MezoConfig(eps=1e-3, lr=1e-2, n_directions=k,
                              momentum=0.9, momentum_window=3)
    strat = build_strategy(est, upd)
    cfg4, cfg2 = mk(4), mk(2)
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    sa, _ = strat.step(
        loss_fn, strat.init_state(jax.tree.map(jnp.copy, params), cfg4),
        batch, jnp.uint32(5), cfg4, mask)
    sb, _ = strat.step(
        loss_fn, strat.init_state(jax.tree.map(jnp.copy, params), cfg2),
        batch, jnp.uint32(5), cfg2)
    tol = (dict(rtol=1e-3, atol=1e-4) if est == "walk"     # walk drift
           else dict(rtol=1e-6, atol=1e-7))
    np.testing.assert_allclose(np.asarray(sa.params["w"]),
                               np.asarray(sb.params["w"]), **tol)


# ---------------------------------------------------------------------------
# satellite: replay_update weight-decay f32 parity (regression)


def test_weight_decay_replay_parity(quad):
    """Live step and replay must use the identical f32 lr*weight_decay
    coefficient -- a Python-float coefficient on the replay side used to
    break bit-exactness."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, weight_decay=0.37)
    p1, aux = mezo_step_vmapdir(loss_fn, jax.tree.map(jnp.copy, params),
                                batch, jnp.uint32(9), cfg)
    p2 = replay_update(jax.tree.map(jnp.copy, params), aux.seed, aux.gs,
                       cfg)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# TrainState checkpointing: momentum history and Adam moments survive


def test_manager_snapshots_full_trainstate(tmp_path, quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, momentum=0.9,
                     momentum_window=3)
    strat = build_strategy("vmapdir", "momentum")
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=2,
                            update_rule=strat.update)
    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    for t in range(5):
        state, aux = strat.step(loss_fn, state, batch, jnp.uint32(t), cfg)
        mgr.on_step(t, state, aux)
    like = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    restored, nxt = CheckpointManager(
        str(tmp_path), mezo_cfg=cfg, snapshot_every=2,
        update_rule=strat.update).restore(like)
    assert nxt == 5
    assert int(restored.step) == 5
    # the whole state roundtrips: params AND the momentum window
    # (snapshot@4 + replay of nothing; the window is non-zero by now)
    assert float(jnp.abs(restored.opt["gs"]).sum()) > 0
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_masked_step_replay_from_log_is_exact(tmp_path, quad):
    """Straggler masks are recorded in the replay log, so a log-tail
    replay renormalizes over the same survivors the live update did."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=4)
    strat = build_strategy("vmapdir", "sgd")
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=3,
                            update_rule=strat.update)
    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    masks = [None, jnp.array([1.0, 0.0, 1.0, 0.0]), None,
             jnp.array([1.0, 1.0, 1.0, 0.0]), jnp.array([0.0, 1.0, 1.0, 1.0])]
    for t, m in enumerate(masks):
        state, aux = strat.step(loss_fn, state, batch, jnp.uint32(t), cfg, m)
        mgr.on_step(t, state, aux, direction_mask=m)
    # snapshot@3 + replay of the masked step 4 must match the live state
    like = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    restored, nxt = CheckpointManager(
        str(tmp_path), mezo_cfg=cfg, snapshot_every=3,
        update_rule=strat.update).restore(like)
    assert nxt == 5
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_without_update_rule_refuses_stateful_opt(tmp_path, quad):
    """A momentum-run checkpoint restored by a manager with no
    update_rule must raise instead of silently replaying with sgd."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, momentum=0.9,
                     momentum_window=3)
    strat = build_strategy("vmapdir", "momentum")
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=2,
                            update_rule=strat.update)
    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    for t in range(4):   # snapshot@2, log tail 3 -> replay needed
        state, aux = strat.step(loss_fn, state, batch, jnp.uint32(t), cfg)
        mgr.on_step(t, state, aux)
    like = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    with pytest.raises(ValueError, match="update_rule"):
        CheckpointManager(str(tmp_path), mezo_cfg=cfg,
                          snapshot_every=2).restore(like)


def test_adam_rejects_estimator_update_flags():
    with pytest.raises(ValueError, match="adam"):
        Trainer(CFG, TrainerConfig(optimizer="adam", estimator="fused"),
                iter(()))


def test_momentum_crash_resume_matches_uninterrupted(tmp_path):
    """Fault injection: snapshot@8 + momentum-rule replay of step 9 +
    live steps 10..11 must equal the uninterrupted run -- i.e. the
    truncated-replay window survives the crash (the old per-step
    functions silently reset it)."""
    n = 12
    mz = MezoConfig(eps=1e-2, lr=1e-2, n_directions=2, momentum=0.9,
                    momentum_window=4)
    tc_a = TrainerConfig(optimizer="mezo-momentum", mezo=mz, n_steps=n,
                         ckpt_dir=str(tmp_path / "a"), snapshot_every=4,
                         log_every=100)
    p_full = Trainer(CFG, tc_a, _batches()).train()

    tc_b = TrainerConfig(optimizer="mezo-momentum", mezo=mz, n_steps=n,
                         ckpt_dir=str(tmp_path / "b"), snapshot_every=4,
                         log_every=100)
    with pytest.raises(RuntimeError):
        Trainer(CFG, tc_b, _batches()).train(fail_at=10)
    tr_c = Trainer(CFG, tc_b, _batches(start=10))
    p_res = tr_c.train()

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


def test_adam_crash_resume_restores_moments(tmp_path):
    """Adam degrades to snapshot-only recovery, but the snapshot now
    carries the full TrainState: resuming must restore mu/nu/count
    instead of silently re-initializing them to zero."""
    n = 8
    tc_a = TrainerConfig(optimizer="adam", adam=AdamConfig(lr=3e-3),
                         n_steps=n, ckpt_dir=str(tmp_path / "a"),
                         snapshot_every=1, log_every=100)
    p_full = Trainer(CFG, tc_a, _batches()).train()

    tc_b = TrainerConfig(optimizer="adam", adam=AdamConfig(lr=3e-3),
                         n_steps=n, ckpt_dir=str(tmp_path / "b"),
                         snapshot_every=1, log_every=100)
    with pytest.raises(RuntimeError):
        Trainer(CFG, tc_b, _batches()).train(fail_at=5)
    tr_c = Trainer(CFG, tc_b, _batches(start=5))
    p_res = tr_c.train()

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# satellite: buffered loss host-sync must not change the history


def test_loss_history_identical_across_log_every():
    tiny = get_config("opt-1.3b").reduced(n_layers=1, d_model=32, d_ff=64,
                                          vocab=64)

    def run(log_every):
        tc = TrainerConfig(optimizer="mezo-parallel",
                           mezo=MezoConfig(eps=1e-2, lr=1e-2,
                                           n_directions=2),
                           n_steps=7, log_every=log_every)
        tr = Trainer(tiny, tc, lm_batches(2, 8, tiny.vocab, seed=0),
                     log_fn=lambda s: None)
        tr.train()
        return tr.losses

    every_step, buffered = run(1), run(1000)
    assert len(buffered) == 7
    assert every_step == buffered


# ---------------------------------------------------------------------------
# chunked multi-step scan


def test_run_chunk_matches_stepwise(quad):
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    strat = build_strategy("vmapdir", "sgd")
    base, n = jnp.uint32(42), 5

    state = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    for i in range(n):
        state, _ = strat.step(loss_fn, state, batch, fold_seed(base, i),
                              cfg)

    stacked = jax.tree.map(lambda x: jnp.stack([x] * n), batch)
    cstate = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    cstate, auxs = strat.run_chunk(loss_fn, cstate, stacked, base, cfg)

    assert int(cstate.step) == n
    assert auxs.gs.shape == (n, cfg.n_directions)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(cstate.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_run_chunk_resumes_step_counter(quad):
    """Chained chunks derive per-step seeds from the carried step counter,
    so two 3-step chunks equal one 6-step chunk."""
    params, batch, loss_fn = quad
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=1)
    strat = build_strategy("vmapdir", "sgd")
    base = jnp.uint32(7)
    stack = lambda k: jax.tree.map(lambda x: jnp.stack([x] * k), batch)

    s6 = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    s6, _ = strat.run_chunk(loss_fn, s6, stack(6), base, cfg)

    s33 = strat.init_state(jax.tree.map(jnp.copy, params), cfg)
    s33, _ = strat.run_chunk(loss_fn, s33, stack(3), base, cfg)
    s33, _ = strat.run_chunk(loss_fn, s33, stack(3), base, cfg)

    assert int(s33.step) == int(s6.step) == 6
    for a, b in zip(jax.tree.leaves(s6.params),
                    jax.tree.leaves(s33.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# back-compat: pre-engine momentum histories (no coeffs row) still step


def test_legacy_momentum_history_upgrades():
    from repro.core import mezo_momentum_step
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (4, 4))}

    def loss_fn(p, _):
        return jnp.sum(p["w"] ** 2) * 1e-2

    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2, momentum=0.9,
                     momentum_window=3)
    old_hist = {"seeds": jnp.zeros((3,), jnp.uint32),
                "gs": jnp.zeros((3, 2), jnp.float32)}
    p, aux, hist = mezo_momentum_step(loss_fn, params, None, jnp.uint32(0),
                                      cfg, old_hist)
    assert set(hist) == {"seeds", "gs", "coeffs"}
    assert np.isfinite(float(aux.loss))
    # upgraded rows carry the -lr/K coefficient the old step applied
    # (rows 0..1 are still the upgraded legacy entries after one roll)
    np.testing.assert_allclose(np.asarray(hist["coeffs"][0]),
                               -cfg.lr / cfg.n_directions, rtol=1e-6)
