"""rwkv6-7b "Finch" [ssm]: attn-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
        n_heads=0, n_kv_heads=0, d_ff=14336, vocab=65536,
        norm="rmsnorm", pos="none", rwkv_head_dim=64, max_seq=524288)
