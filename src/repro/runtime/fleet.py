"""Async elastic direction service: fleet-scale ZO training.

A ZO training step is commutative scalar accumulation of ``(seed, gs)``
pairs, which tolerates asynchrony far better than gradient descent: a
stale projected gradient is still an unbiased directional sample at a
nearby point. This module exploits that to train over a fleet of
heterogeneous, flaky device-grade workers (the paper's single OPPO
Reno 6 generalized to millions of phones):

* a :class:`FleetCoordinator` owns the authoritative params and hands
  out ``(step, seed, k)`` **direction leases** to whichever worker asks;
* workers evaluate the K perturbed-forward pairs against whatever params
  version they snapshotted at lease time and return ``gs`` at their own
  pace (device grades modeled by the roofline latency profiles in
  :mod:`repro.roofline.analysis`);
* the coordinator applies each result **staleness-decayed** -- the
  update scaled by ``staleness_decay ** (version_now - version_at_
  snapshot)`` through the ``stale-sgd`` update rule -- and records the
  applied update (staleness + survivor mask included) in the replay log;
* lease expiry reuses :meth:`StragglerPolicy.deadline` (EMA-median
  latency budget): an overdue step is re-issued to the next idle worker,
  and whichever result arrives first wins -- late or duplicate
  deliveries are dropped, never logged;
* worker join/leave mid-round resizes the straggler policy and re-shards
  the authoritative params via ``elastic_mesh`` / ``remesh_params``
  (values untouched).

**Bit-replayability across all of this** is by construction: the live
coordinator applies every update by calling
:func:`repro.checkpoint.replay_log.replay_into` on the very record it
just logged, so replaying the log from theta_0 re-executes the identical
eager f32 arithmetic in the identical order -- elastic resizes, expired
leases, and dropped duplicates leave no trace beyond the records that
were actually applied.

:class:`FleetSim` drives a coordinator + in-process worker pool through
a deterministic discrete-event simulation (virtual time, heap-ordered
deliveries) with injectable per-worker latency/death/duplicate-delivery
faults -- the test and benchmark harness for the service. The
coordinator API itself is transport-agnostic: ``next_lease`` / ``submit``
are what an RPC front end would expose to real devices.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import time
from collections import deque
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.replay_log import ReplayLog, replay_into
from repro.core import rng as zrng
from repro.core.engine import MezoConfig, build_strategy
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import active_params, model_flops
from repro.runtime.elastic import elastic_mesh, remesh_params
from repro.runtime.stragglers import StragglerPolicy

PyTree = Any


# ---------------------------------------------------------------------------
# device grades (roofline latency profiles)


@dataclasses.dataclass(frozen=True)
class DeviceGrade:
    """A worker's hardware envelope. Lease latency is the classic
    two-term roofline: max(FLOPs / peak, bytes / bandwidth)."""
    name: str
    peak_flops: float            # FLOP/s
    mem_bw: float                # bytes/s


DEVICE_GRADES: Dict[str, DeviceGrade] = {
    # a server-class accelerator chip (v5e numbers from launch.mesh)
    "server": DeviceGrade("server", PEAK_FLOPS_BF16, HBM_BW),
    # phone SoC grades, the paper's regime: flagship NPU down to a
    # budget part -- order-of-magnitude figures, what matters is the
    # relative spread the scheduler has to absorb
    "flagship": DeviceGrade("flagship", 2.0e12, 60e9),
    "midrange": DeviceGrade("midrange", 5.0e11, 30e9),
    "budget": DeviceGrade("budget", 1.2e11, 12e9),
}


def get_grade(name: str) -> DeviceGrade:
    if name not in DEVICE_GRADES:
        raise ValueError(f"unknown device grade {name!r}; registered: "
                         f"{sorted(DEVICE_GRADES)}")
    return DEVICE_GRADES[name]


def lease_latency_s(model_cfg, grade: DeviceGrade, n_tokens: int,
                    k: int) -> float:
    """Modeled seconds for one direction lease on a device grade: K
    directions x 2 perturbed forwards over ``n_tokens``, each forward
    streaming the active params once (ZO adds no optimizer traffic)."""
    flops = model_flops(model_cfg, n_tokens, "train") * k   # 4*N*D per dir
    bytes_ = 2.0 * k * 4.0 * active_params(model_cfg)       # 2 fwd, f32
    return max(flops / grade.peak_flops, bytes_ / grade.mem_bw)


# ---------------------------------------------------------------------------
# workers and faults


@dataclasses.dataclass
class FaultSpec:
    """Injectable per-worker failure modes (all deterministic given the
    sim seed)."""
    latency_scale: float = 1.0       # >1: a straggler
    jitter: float = 0.05             # +-fraction of modeled latency
    die_at: Optional[float] = None   # virtual seconds; kills in-flight work
    duplicate_every: int = 0         # deliver every Nth result twice
    drop_directions: int = 0         # per lease: trailing dirs it fails


@dataclasses.dataclass
class WorkerSpec:
    grade: str = "flagship"
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)


@dataclasses.dataclass
class DirectionLease:
    """One step's direction-evaluation assignment. ``version`` is the
    coordinator's applied-update count when the worker snapshotted
    ``params`` -- staleness at apply time is measured against it."""
    step: int
    seed: int                        # uint32 step seed (fold of run seed)
    k: int                           # directions in the lease
    version: int
    params: PyTree                   # immutable snapshot reference
    worker: int
    issued_at: float
    expired: bool = False


# ---------------------------------------------------------------------------
# the coordinator


class FleetCoordinator:
    """Authoritative state owner of an async direction-service run.

    Transport-agnostic: :meth:`next_lease` and :meth:`submit` are the
    whole device-facing protocol. Everything applied is appended to the
    replay log (staleness + survivor mask included) and the live apply
    goes *through* ``replay_into`` on the freshly built record, so the
    log is bit-exact replayable by construction -- across lease
    re-issues, dropped duplicates, and elastic resizes alike.
    """

    def __init__(self, params: PyTree, cfg: MezoConfig, *,
                 total_steps: int, n_workers: int, seed: int = 0,
                 deadline_factor: float = 3.0, ema: float = 0.9,
                 log_path: Optional[str] = None, remesh: bool = False):
        if total_steps < 1:
            raise ValueError(f"total_steps must be >= 1, got {total_steps}")
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if not 0.0 < cfg.staleness_decay <= 1.0:
            raise ValueError(
                f"staleness_decay must be in (0, 1], got "
                f"{cfg.staleness_decay} (1.0 = no decay; 0 would zero "
                f"every stale update instead of down-weighting it)")
        self.params = params
        self.cfg = cfg
        self.total_steps = total_steps
        self.seed = seed
        self.deadline_factor = deadline_factor
        self.ema = ema
        self.remesh = remesh
        self.version = 0                       # applied-update count
        self.records: List[dict] = []          # applied, in apply order
        self.losses: List[float] = []          # at-eval loss per apply
        self.log = ReplayLog(log_path) if log_path else None

        self._roster: List[int] = list(range(n_workers))
        self._next_wid = n_workers
        self._issued = 0                       # next fresh step id
        self._applied: set = set()
        self._reissue: deque = deque()
        self._inflight: Dict[int, List[DirectionLease]] = {}
        self.policy = StragglerPolicy(n_workers,
                                      deadline_factor=deadline_factor,
                                      ema=ema)
        self.reissued = 0
        self.dropped = 0                       # late/duplicate deliveries
        self.resizes = 0

    # ---- leases ---------------------------------------------------------
    def done(self) -> bool:
        return len(self._applied) >= self.total_steps

    def step_seed(self, step: int) -> int:
        return int(np.asarray(zrng.fold_seed(jnp.uint32(self.seed),
                                             jnp.uint32(step))))

    def next_lease(self, worker: int, now: float
                   ) -> Optional[DirectionLease]:
        """Hand the calling worker a direction lease: an expired step to
        re-evaluate if one is overdue, else the next fresh step. None
        when there is nothing to do right now (all remaining steps are
        in flight within deadline)."""
        self.expire(now)
        while self._reissue and self._reissue[0] in self._applied:
            self._reissue.popleft()
        if self._reissue:
            step = self._reissue.popleft()
            self.reissued += 1
        elif self._issued < self.total_steps:
            step = self._issued
            self._issued += 1
        else:
            return None
        lease = DirectionLease(step=step, seed=self.step_seed(step),
                               k=self.cfg.n_directions,
                               version=self.version, params=self.params,
                               worker=worker, issued_at=now)
        self._inflight.setdefault(step, []).append(lease)
        return lease

    def expire(self, now: float):
        """Mark overdue leases expired (StragglerPolicy deadline: a
        ``deadline_factor`` multiple of the EMA-median latency) and
        queue their steps for re-issue once no un-expired lease is still
        chasing them. Expired leases may still deliver -- first result
        wins regardless; expiry only buys redundancy."""
        budget = self.policy.deadline()
        if math.isinf(budget):
            return
        for step, leases in self._inflight.items():
            if step in self._applied:
                continue
            for lease in leases:
                if not lease.expired and now - lease.issued_at > budget:
                    lease.expired = True
            if (all(lease.expired for lease in leases)
                    and step not in self._reissue):
                self._reissue.append(step)

    # ---- results --------------------------------------------------------
    def submit(self, lease: DirectionLease, gs, now: float, mask=None,
               loss: Optional[float] = None) -> bool:
        """Deliver a lease's ``gs``. Returns True iff the update was
        applied; False means the step was already applied (a late or
        duplicate delivery) and the result was dropped -- dropped
        results never reach the log."""
        self._observe(lease.worker, now - lease.issued_at)
        if lease.step in self._applied:
            self.dropped += 1
            return False
        rec = {"step": int(lease.step), "seed": int(lease.seed),
               "gs": np.asarray(gs, np.float32).reshape(-1).tolist(),
               "lr": float(self.cfg.lr), "eps": float(self.cfg.eps),
               "staleness": int(self.version - lease.version)}
        if mask is not None:
            rec["mask"] = np.asarray(mask,
                                     np.float32).reshape(-1).tolist()
        # apply THROUGH the replay path: live params advance by exactly
        # the arithmetic a later replay of this record will re-execute
        self.params, _ = replay_into(self.params, [rec], self.cfg)
        self.version += 1
        self._applied.add(lease.step)
        self._inflight.pop(lease.step, None)
        self.records.append(rec)
        if loss is not None:
            self.losses.append(float(loss))
        if self.log is not None:
            self.log.append(rec["step"], rec["seed"], rec["gs"],
                            rec["lr"], rec["eps"], mask=rec.get("mask"),
                            staleness=rec["staleness"])
        return True

    def _observe(self, worker: int, latency: float):
        if worker not in self._roster:
            return                      # delivery from a departed worker
        vec = (self.policy.ema_latencies if self.policy.seen
               else np.full(self.policy.total, latency))
        vec[self._roster.index(worker)] = latency
        self.policy.observe(vec)

    # ---- elastic resize -------------------------------------------------
    def worker_join(self, now: float) -> int:
        """Admit a new worker mid-round: grow the straggler policy
        (seeding the newcomer's EMA with the fleet median) and re-shard
        params onto the resized mesh. Returns the new worker id."""
        wid = self._next_wid
        self._next_wid += 1
        carried = (np.append(self.policy.ema_latencies,
                             np.median(self.policy.ema_latencies))
                   if self.policy.seen else None)
        self._roster.append(wid)
        self._resize(carried)
        return wid

    def worker_leave(self, wid: int, now: float):
        """Retire a worker: orphan its in-flight leases (their steps go
        back on the re-issue queue), shrink the policy, re-shard."""
        if wid not in self._roster:
            raise ValueError(f"worker {wid} is not in the roster "
                             f"{self._roster}")
        idx = self._roster.index(wid)
        carried = (np.delete(self.policy.ema_latencies, idx)
                   if self.policy.seen and len(self._roster) > 1 else None)
        self._roster.remove(wid)
        for step, leases in self._inflight.items():
            if step in self._applied:
                continue
            for lease in leases:
                if lease.worker == wid:
                    lease.expired = True
            if (all(lease.expired for lease in leases)
                    and step not in self._reissue):
                self._reissue.append(step)
        self._resize(carried)

    def _resize(self, carried_latencies: Optional[np.ndarray]):
        self.policy = StragglerPolicy(max(len(self._roster), 1),
                                      deadline_factor=self.deadline_factor,
                                      ema=self.ema)
        if carried_latencies is not None and len(self._roster):
            self.policy.observe(carried_latencies)
        if self.remesh:
            # pod-elastic param move: values untouched (a device_put),
            # so the replay-log contract survives the resize
            mesh = elastic_mesh(jax.devices(), model_parallel=1,
                                data_parallel=1)
            self.params = remesh_params(self.params, mesh)
        self.resizes += 1

    def close(self):
        if self.log is not None:
            self.log.close()


# ---------------------------------------------------------------------------
# the in-process worker pool (deterministic discrete-event simulation)


@dataclasses.dataclass
class FleetReport:
    applied: int
    issued: int                      # leases handed out (re-issues incl.)
    reissued: int
    dropped: int                     # late/duplicate deliveries discarded
    resizes: int
    virtual_s: float                 # modeled fleet makespan
    wall_s: float
    losses: List[float]              # at-eval loss per applied update
    staleness: List[int]             # per applied update, apply order
    records: List[dict]
    params: PyTree

    @property
    def virtual_steps_per_s(self) -> float:
        return self.applied / self.virtual_s if self.virtual_s else 0.0


@dataclasses.dataclass
class _SimWorker:
    wid: int
    spec: WorkerSpec
    grade: DeviceGrade
    alive: bool = True
    lease: Optional[DirectionLease] = None
    deliveries: int = 0


@partial(jax.jit, static_argnames=("loss_fn", "cfg", "eval_fn"))
def _jit_eval(loss_fn, params, batch, seed, cfg, eval_fn):
    """One direction lease's device work: K perturbed-forward pairs ->
    ((K,) gs, mean loss). ``eval_fn`` is a pristine DirectionEvaluator's
    eval_fn -- the snapshot params are shared by reference and must
    never be written."""
    _, gs, ls = eval_fn(loss_fn, params, batch, seed, cfg)
    return gs, ls.mean()


class FleetSim:
    """Deterministic event-driven fleet: virtual-time worker pool around
    a :class:`FleetCoordinator`.

    ``batches``: step -> host batch dict (every worker evaluating step t
    sees the same batch -- a re-issued lease differs only in its params
    snapshot). ``events``: scheduled elastic events,
    ``("join", t, WorkerSpec)`` / ``("leave", t, wid)`` at virtual time
    ``t``; per-worker ``FaultSpec.die_at`` deaths are leave events that
    also discard the worker's in-flight result. ``step_events`` are the
    applied-count-triggered form -- ``(after_applied, kind, payload)``
    fires as soon as ``after_applied`` updates have been applied --
    which pins "join/leave mid-round" deterministically regardless of
    the modeled latency scale (virtual-time events can land after a
    short run's makespan and never fire).
    """

    def __init__(self, model_cfg, workers: Sequence[WorkerSpec], *,
                 total_steps: int, mezo_cfg: Optional[MezoConfig] = None,
                 batches: Optional[Callable[[int], dict]] = None,
                 batch: int = 2, seq: int = 16, seed: int = 0,
                 estimator: str = "fused", deadline_factor: float = 3.0,
                 ema: float = 0.9, log_path: Optional[str] = None,
                 events: Sequence[Tuple] = (),
                 step_events: Sequence[Tuple] = (), remesh: bool = True):
        from repro.models import build_model

        if not workers:
            raise ValueError("FleetSim needs at least one worker")
        strat = build_strategy(estimator, "stale-sgd")
        if not strat.estimator.pristine:
            raise ValueError(
                f"fleet workers share params snapshots by reference and "
                f"need a pristine direction estimator (vmapdir/fused), "
                f"got {estimator!r}: the in-place walk would corrupt "
                f"co-leased snapshots")
        self._eval_fn = strat.estimator.eval_fn
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.cfg = mezo_cfg or MezoConfig()
        self.seed = seed
        self.base_params = self.model.init(jax.random.PRNGKey(seed))
        self.batches = batches or default_batches(model_cfg, batch, seq,
                                                  seed)
        b0 = self.batches(0)
        first = b0.get("tokens", next(iter(b0.values())))
        self.n_tokens = int(np.asarray(first).size)
        self.coord = FleetCoordinator(
            self.base_params, self.cfg, total_steps=total_steps,
            n_workers=len(workers), seed=seed,
            deadline_factor=deadline_factor, ema=ema, log_path=log_path,
            remesh=remesh)
        self.workers: Dict[int, _SimWorker] = {
            i: _SimWorker(i, spec, get_grade(spec.grade))
            for i, spec in enumerate(workers)}
        self._heap: list = []
        self._seq = 0
        self._events = list(events)
        self._step_events = sorted(step_events, key=lambda e: e[0])

    # ---- event plumbing -------------------------------------------------
    def _push(self, at: float, kind: str, payload):
        heapq.heappush(self._heap, (at, self._seq, kind, payload))
        self._seq += 1

    def _latency(self, w: _SimWorker, lease: DirectionLease) -> float:
        base = lease_latency_s(self.model_cfg, w.grade, self.n_tokens,
                               lease.k)
        u = np.random.default_rng(
            (self.seed, w.wid, lease.step)).uniform(-1.0, 1.0)
        return base * w.spec.faults.latency_scale * (
            1.0 + w.spec.faults.jitter * u)

    def _assign(self, now: float):
        for w in self.workers.values():
            if not w.alive or w.lease is not None:
                continue
            lease = self.coord.next_lease(w.wid, now)
            if lease is None:
                continue
            w.lease = lease
            done_at = now + self._latency(w, lease)
            self._push(done_at, "done", (w.wid, lease))
            budget = self.coord.policy.deadline()
            if not math.isinf(budget):
                # a timer so idle workers pick up the re-issue the
                # moment the lease goes overdue, not at the next
                # unrelated delivery
                self._push(lease.issued_at + budget * 1.001, "expire",
                           None)

    def _evaluate(self, w: _SimWorker, lease: DirectionLease):
        batch = {k: jnp.asarray(v) for k, v in
                 self.batches(lease.step).items()}
        gs, loss = _jit_eval(self.model.loss, lease.params, batch,
                             jnp.uint32(lease.seed), self.cfg,
                             self._eval_fn)
        gs = np.asarray(gs, np.float32)
        mask = None
        d = w.spec.faults.drop_directions
        if d:
            mask = np.ones(lease.k, np.float32)
            mask[lease.k - min(d, lease.k - 1):] = 0.0
        return gs, mask, float(loss)

    # ---- event handlers -------------------------------------------------
    def _on_done(self, now: float, wid: int, lease: DirectionLease,
                 result=None):
        w = self.workers.get(wid)
        if w is None or not w.alive:
            return                            # died while computing
        if result is None:                    # first delivery: evaluate
            if w.lease is not lease:
                return                        # stale event (superseded)
            w.lease = None
            result = self._evaluate(w, lease)
            w.deliveries += 1
            dup = w.spec.faults.duplicate_every
            if dup and w.deliveries % dup == 0:
                # the transport delivers the same result again shortly
                # (a fraction of this worker's own lease latency, so the
                # dup lands among other deliveries at any model scale)
                self._push(now + 0.1 * self._latency(w, lease),
                           "done_dup", (wid, lease, result))
        gs, mask, loss = result
        self.coord.submit(lease, gs, now, mask=mask, loss=loss)

    def _on_leave(self, now: float, wid: int):
        w = self.workers.get(wid)
        if w is None or not w.alive:
            return
        w.alive = False
        w.lease = None
        self.coord.worker_leave(wid, now)

    def _on_join(self, now: float, spec: WorkerSpec):
        wid = self.coord.worker_join(now)
        self.workers[wid] = _SimWorker(wid, spec, get_grade(spec.grade))
        if spec.faults.die_at is not None:
            self._push(spec.faults.die_at, "leave", wid)

    # ---- the run --------------------------------------------------------
    def run(self) -> FleetReport:
        t0 = time.perf_counter()
        now = 0.0
        for ev in self._events:
            kind, at, payload = ev
            if kind not in ("join", "leave"):
                raise ValueError(f"unknown fleet event kind {kind!r}; "
                                 f"expected ('join'|'leave', time, "
                                 f"payload)")
            self._push(float(at), kind, payload)
        for after, kind, _ in self._step_events:
            if kind not in ("join", "leave"):
                raise ValueError(f"unknown fleet step-event kind "
                                 f"{kind!r}; expected (after_applied, "
                                 f"'join'|'leave', payload)")
            if after >= self.coord.total_steps:
                raise ValueError(
                    f"step event at after_applied={after} can never "
                    f"fire: the run applies {self.coord.total_steps} "
                    f"update(s) and stops")
        for w in self.workers.values():
            if w.spec.faults.die_at is not None:
                self._push(w.spec.faults.die_at, "leave", w.wid)
        self._assign(now)
        while not self.coord.done():
            if not self._heap:
                raise RuntimeError(
                    f"fleet stalled at t={now:.3f}s with "
                    f"{len(self.coord._applied)}/{self.coord.total_steps}"
                    f" steps applied and no live workers or pending "
                    f"events")
            now, _, kind, payload = heapq.heappop(self._heap)
            if kind == "done":
                self._on_done(now, *payload)
            elif kind == "done_dup":
                self._on_done(now, payload[0], payload[1],
                              result=payload[2])
            elif kind == "leave":
                self._on_leave(now, payload)
            elif kind == "join":
                self._on_join(now, payload)
            # "expire" carries no payload: expiry is re-checked inside
            # next_lease; the event just forces an assignment pass
            while (self._step_events and
                   len(self.coord._applied) >= self._step_events[0][0]):
                _, ekind, payload = self._step_events.pop(0)
                if ekind == "join":
                    self._on_join(now, payload)
                else:
                    self._on_leave(now, payload)
            self._assign(now)
        self.coord.close()
        c = self.coord
        return FleetReport(
            applied=len(c._applied), issued=c._issued + c.reissued,
            reissued=c.reissued, dropped=c.dropped, resizes=c.resizes,
            virtual_s=now, wall_s=time.perf_counter() - t0,
            losses=list(c.losses),
            staleness=[r["staleness"] for r in c.records],
            records=list(c.records), params=c.params)


def default_batches(model_cfg, batch: int, seq: int, seed: int
                    ) -> Callable[[int], dict]:
    """Deterministic step-indexed LM batches (the fleet analogue of
    ``launch.train_fleet.user_batches``): every worker evaluating step t
    draws the identical batch, so a re-issued lease's gs differs only
    through its params snapshot."""
    def fn(step: int):
        rng = np.random.default_rng((seed, step))
        toks = rng.integers(0, model_cfg.vocab, (batch, seq + 1),
                            dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "loss_mask": np.ones((batch, seq), np.float32)}
    return fn
