"""MeZO: memory-efficient zeroth-order fine-tuning (PocketLLM's method).

Implements SPSA (Spall 1992) with MeZO's seed-replay storage trick
(Malladi et al. 2024), as adopted by PocketLLM for on-device fine-tuning:

    z ~ RNG(seed)          (regenerated, never stored)
    l+ = L(theta + eps z);  l- = L(theta - eps z)
    g  = (l+ - l-) / (2 eps)
    theta <- theta - lr * g * z

Three execution strategies:

* ``mezo_step`` -- sequential over K directions with the *in-place walk*
  (perturb / eval / counter-perturb / eval / restore-fused-with-update):
  peak memory = params + one forward's activations. This is the
  paper-faithful memory profile (PocketLLM Table 1). Cost: 3 full
  parameter sweeps per direction on top of the 2 forwards.

* ``mezo_step_vmapdir`` -- vmaps direction evaluation so a pod axis can
  evaluate directions concurrently (PocketLLM Sec 6.3's "inherent
  parallelization potential", realized). Costs one extra transient param
  copy per device; cross-pod traffic is K scalars, not N gradients.

* ``mezo_step_fused`` -- the perturbation never touches the parameters at
  all: a :class:`repro.core.perturb_ctx.PerturbCtx` with ``coeff=+/-eps``
  rides into the forward and each dense projection computes
  ``X @ (W + coeff*z)`` via the fused Pallas kernel (z regenerated in
  VMEM). 0 param sweeps per direction, no whole-tree transient copy;
  non-matmul leaves (norm scales, gated MLP weights, tied unembeds) fall
  back to a transient leaf-sized ``coeff*z``, and the only remaining
  sweep is the shared seed-replay update. Requires a loss_fn that
  accepts ``perturb=`` (models built by repro.models.build_model do;
  families without a wired fused forward fall back to one transient
  materialized copy, the vmapdir memory profile).

All return the new params plus a :class:`MezoAux` record whose
``(seed, gs)`` pair is exactly what the replay-log checkpointer persists
(~12 bytes/step/direction) -- see repro/checkpoint/replay_log.py. The
fused step shares the update arithmetic of ``mezo_step_vmapdir``
(pristine base point), so its replay is bit-exact.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import rng as zrng
from repro.core.perturb import add_scaled_z
from repro.core.perturb_ctx import PerturbCtx

PyTree = Any
LossFn = Callable[[PyTree, Any], jnp.ndarray]  # (params, batch) -> scalar


@dataclasses.dataclass(frozen=True)
class MezoConfig:
    eps: float = 1e-3
    lr: float = 1e-6
    n_directions: int = 1          # K: SPSA directions averaged per step
    dist: str = "rademacher"       # or "gaussian" (MeZO-repo default)
    use_kernel: bool = False       # route 2-D leaves via Pallas zo_add
    momentum: float = 0.0          # ZO momentum via truncated seed replay
    momentum_window: int = 8       # directions of history to replay
    weight_decay: float = 0.0


@dataclasses.dataclass
class MezoAux:
    loss: jnp.ndarray         # mean of (l+ + l-)/2 over directions
    gs: jnp.ndarray           # (K,) projected gradients -- the replay log
    seed: jnp.ndarray         # uint32 step seed -- the replay log
    grad_norm_est: jnp.ndarray


jax.tree_util.register_pytree_node(
    MezoAux,
    lambda a: ((a.loss, a.gs, a.seed, a.grad_norm_est), None),
    lambda _, c: MezoAux(*c),
)


def _apply_direction_updates(params, seed, gs, coeffs, cfg: MezoConfig):
    """theta += sum_k coeffs[k] * gs[k] * z_k, z_k regenerated per k."""
    k_tot = gs.shape[0]

    def body(p, kg):
        k, g, c = kg
        return add_scaled_z(p, zrng.fold_seed(seed, k), c * g,
                            dist=cfg.dist, use_kernel=cfg.use_kernel), None

    params, _ = jax.lax.scan(
        body, params, (jnp.arange(k_tot, dtype=jnp.uint32), gs, coeffs))
    return params


def _decay(params, wd_coeff):
    if wd_coeff is None:
        return params
    return jax.tree.map(
        lambda p: (p * (1.0 - wd_coeff)).astype(p.dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


@partial(jax.jit, static_argnames=("loss_fn", "cfg"), donate_argnums=(1,))
def mezo_step(loss_fn: LossFn, params: PyTree, batch: Any, seed,
              cfg: MezoConfig, direction_mask=None):
    """Paper-faithful sequential MeZO step (in-place walk, donated params).

    direction_mask: optional (K,) 0/1 floats -- straggler mitigation drops
    late directions; the update renormalizes over survivors (an unbiased
    lower-sample SPSA estimate, unique to ZO: no gradient shard is lost).
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)
    lr = jnp.float32(cfg.lr)
    kk = cfg.n_directions

    def one_dir(p, k):
        s = zrng.fold_seed(seed, k)
        p = add_scaled_z(p, s, eps, dist=cfg.dist, use_kernel=cfg.use_kernel)
        lp = loss_fn(p, batch)
        p = add_scaled_z(p, s, -2.0 * eps, dist=cfg.dist,
                         use_kernel=cfg.use_kernel)
        lm = loss_fn(p, batch)
        # restore to base point for the next direction
        p = add_scaled_z(p, s, eps, dist=cfg.dist, use_kernel=cfg.use_kernel)
        g = (lp - lm) / (2.0 * eps)
        return p, (g, 0.5 * (lp + lm))

    params, (gs, ls) = jax.lax.scan(
        one_dir, params, jnp.arange(kk, dtype=jnp.uint32))
    return _finish_step(params, seed, gs, ls, lr, direction_mask, cfg)


def _direction_coeffs(kk: int, lr, direction_mask):
    if direction_mask is None:
        return jnp.full((kk,), -lr / kk, jnp.float32)
    m = jnp.asarray(direction_mask, jnp.float32).reshape(kk)
    return -lr * m / jnp.maximum(m.sum(), 1.0)


def _finish_step(params, seed, gs, ls, lr, direction_mask, cfg: MezoConfig):
    """Shared update tail of every strategy: identical f32 arithmetic here
    is what makes the (seed, gs) replay log interchangeable across them
    (and bit-exact for the pristine-base-point strategies)."""
    coeffs = _direction_coeffs(cfg.n_directions, lr, direction_mask)
    if cfg.weight_decay:
        params = _decay(params, lr * cfg.weight_decay)
    params = _apply_direction_updates(params, seed, gs, coeffs, cfg)
    aux = MezoAux(loss=ls.mean(), gs=gs, seed=seed,
                  grad_norm_est=jnp.abs(gs).mean())
    return params, aux


@partial(jax.jit, static_argnames=("loss_fn", "cfg"))
def mezo_step_vmapdir(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                      cfg: MezoConfig, direction_mask=None):
    """Direction-parallel MeZO step.

    The K-way vmap axis is what the launcher shards over the ``pod`` mesh
    axis (see launch/train.py): each pod evaluates its directions on the
    full (data-sharded) batch; the only cross-pod exchange is the (K,)
    vector ``gs``.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)
    lr = jnp.float32(cfg.lr)
    kk = cfg.n_directions

    def eval_dir(k):
        s = zrng.fold_seed(seed, k)
        lp = loss_fn(add_scaled_z(params, s, eps, dist=cfg.dist), batch)
        lm = loss_fn(add_scaled_z(params, s, -eps, dist=cfg.dist), batch)
        return (lp - lm) / (2.0 * eps), 0.5 * (lp + lm)

    gs, ls = jax.vmap(eval_dir)(jnp.arange(kk, dtype=jnp.uint32))
    return _finish_step(params, seed, gs, ls, lr, direction_mask, cfg)


@partial(jax.jit, static_argnames=("loss_fn", "cfg"), donate_argnums=(1,))
def mezo_step_fused(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                    cfg: MezoConfig, direction_mask=None):
    """Fused perturbed-forward MeZO step: 0 param sweeps per direction.

    l+ and l- are evaluated with ``coeff=+/-eps`` carried into the forward
    by a :class:`PerturbCtx` -- params are read-only until the final
    seed-replay update, which is shared with the other strategies (so the
    (seed, gs) replay log stays interchangeable). ``loss_fn`` must accept
    a ``perturb=`` keyword; both sides of each direction see the exact
    z-fields ``add_scaled_z`` would apply, so losses match
    ``mezo_step_vmapdir`` bit-for-bit on the jnp path in f32.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)
    lr = jnp.float32(cfg.lr)
    kk = cfg.n_directions

    def one_dir(_, k):
        s = zrng.fold_seed(seed, k)
        ctx = PerturbCtx(seed=s, coeff=eps, dist=cfg.dist,
                         use_kernel=cfg.use_kernel)
        lp = loss_fn(params, batch, perturb=ctx)
        lm = loss_fn(params, batch,
                     perturb=dataclasses.replace(ctx, coeff=-eps))
        return None, ((lp - lm) / (2.0 * eps), 0.5 * (lp + lm))

    _, (gs, ls) = jax.lax.scan(one_dir, None,
                               jnp.arange(kk, dtype=jnp.uint32))
    return _finish_step(params, seed, gs, ls, lr, direction_mask, cfg)


@partial(jax.jit, static_argnames=("loss_fn", "cfg"), donate_argnums=(1,))
def mezo_momentum_step(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                       cfg: MezoConfig, hist):
    """ZO-momentum via truncated seed replay (paper Sec 6.2 asks for
    faster derivative-free methods).

    Classical momentum needs a param-sized velocity buffer -- exactly the
    memory MeZO exists to avoid. But the ZO velocity is structurally
      v_t = sum_i beta^{t-i} g_i z_i,
    so a truncated window of M (seed, g) PAIRS represents it in O(M)
    scalars; the update replays the last M directions with geometric
    weights. Memory: M*(K+1) scalars. Compute: M extra z-regeneration
    sweeps per step (bandwidth-bound, no forwards).

    hist: {"seeds": (M,) uint32, "gs": (M, K) f32} (zeros = empty slots;
    g=0 entries are no-ops). Returns (params, aux, new_hist).
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)
    lr = jnp.float32(cfg.lr)
    kk = cfg.n_directions
    beta = jnp.float32(cfg.momentum)
    m = cfg.momentum_window

    def eval_dir(k):
        s = zrng.fold_seed(seed, k)
        lp = loss_fn(add_scaled_z(params, s, eps, dist=cfg.dist), batch)
        lm = loss_fn(add_scaled_z(params, s, -eps, dist=cfg.dist), batch)
        return (lp - lm) / (2.0 * eps), 0.5 * (lp + lm)

    gs, ls = jax.vmap(eval_dir)(jnp.arange(kk, dtype=jnp.uint32))

    # roll the window: newest last
    seeds_h = jnp.concatenate([hist["seeds"][1:], seed[None]])
    gs_h = jnp.concatenate([hist["gs"][1:], gs[None]])

    # apply sum_j beta^(M-1-j) * (-lr/K) * g_jk * z(seed_j, k)
    ages = jnp.arange(m - 1, -1, -1, dtype=jnp.float32)
    weights = (1.0 - beta) * beta ** ages if cfg.momentum else         jnp.where(ages == 0, 1.0, 0.0)

    def body(p, inp):
        s_j, g_j, w_j = inp

        def dir_body(pp, kg):
            k, g = kg
            return add_scaled_z(pp, zrng.fold_seed(s_j, k),
                                -lr * w_j * g / kk, dist=cfg.dist), None
        p, _ = jax.lax.scan(
            dir_body, p, (jnp.arange(kk, dtype=jnp.uint32), g_j))
        return p, None

    if cfg.weight_decay:
        params = _decay(params, lr * cfg.weight_decay)
    params, _ = jax.lax.scan(body, params, (seeds_h, gs_h, weights))
    aux = MezoAux(loss=ls.mean(), gs=gs, seed=seed,
                  grad_norm_est=jnp.abs(gs).mean())
    return params, aux, {"seeds": seeds_h, "gs": gs_h}


def momentum_history_init(cfg: MezoConfig):
    return {"seeds": jnp.zeros((cfg.momentum_window,), jnp.uint32),
            "gs": jnp.zeros((cfg.momentum_window, cfg.n_directions),
                            jnp.float32)}


def replay_update(params: PyTree, seed, gs, cfg: MezoConfig):
    """Re-apply a logged step's update from its (seed, gs) record.

    This is the recovery path of the replay-log checkpointer: a crashed
    worker reconstructs theta_t from theta_0 and the scalar log at memory
    bandwidth, with zero forward passes.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    gs = jnp.asarray(gs, jnp.float32).reshape(-1)
    # identical f32 arithmetic to the live step -> bit-exact replay
    coeffs = _direction_coeffs(gs.shape[0], jnp.float32(cfg.lr), None)
    if cfg.weight_decay:
        params = _decay(params, cfg.lr * cfg.weight_decay)
    return _apply_direction_updates(params, seed, gs, coeffs, cfg)


def spsa_gradient_estimate(loss_fn: LossFn, params: PyTree, batch: Any,
                           seed, cfg: MezoConfig) -> PyTree:
    """Materialized SPSA gradient estimate: mean_k g_k * z_k.

    Only for tests / analysis -- production paths never materialize z.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    eps = jnp.float32(cfg.eps)

    def est(k):
        s = zrng.fold_seed(seed, k)
        lp = loss_fn(add_scaled_z(params, s, eps, dist=cfg.dist), batch)
        lm = loss_fn(add_scaled_z(params, s, -eps, dist=cfg.dist), batch)
        g = (lp - lm) / (2.0 * eps)
        zero = jax.tree.map(jnp.zeros_like, params)
        return add_scaled_z(zero, s, g, dist=cfg.dist)

    grads = [est(jnp.uint32(k)) for k in range(cfg.n_directions)]
    return jax.tree.map(lambda *xs: sum(xs) / len(xs), *grads)
