"""Quantized-base runtime: quantize/dequant roundtrip properties, fused
dequant+perturb kernel parity, update/replay semantics over int8 bases.

The hypothesis property suites need the optional ``hypothesis`` dep and
auto-skip without it (like tests/test_property.py); the deterministic
tests below them always run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import MezoConfig, PerturbCtx, add_scaled_z, build_strategy
from repro.core import rng as zrng
from repro.kernels import ops
from repro.optim import compression
from repro.optim.quant import (QuantizedLeaf, default_quantizable, deq,
                               dequantize_tree, is_quantized, quantize_leaf,
                               quantize_tree, quantized_bytes, take_rows,
                               tree_is_quantized, with_delta)
from repro.serve.adapters import AdapterStore

KEY = jax.random.PRNGKey(0)


def _tiny_tree(seed=1):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "a": {"w": jax.random.normal(ks[0], (16, 8), jnp.float32) * 0.1},
        "blocks": {"ln": jax.random.normal(ks[1], (2, 8), jnp.float32),
                   "w": jax.random.normal(ks[2], (2, 8, 16),
                                          jnp.float32) * 0.1},
        "b": jax.random.normal(ks[3], (8,), jnp.float32) * 0.1,
    }


# ---------------------------------------------------------------------------
# deterministic structure / edge-case tests


def test_quantize_tree_structure_and_bytes():
    tree = _tiny_tree()
    qt = quantize_tree(tree)
    assert is_quantized(qt["a"]["w"])
    assert is_quantized(qt["blocks"]["w"])       # stacked rank-3: matrix
    assert not is_quantized(qt["blocks"]["ln"])  # stacked rank-2: vector
    assert not is_quantized(qt["b"])             # rank-1
    # stacked leaves keep the leading layer axis on values AND scales
    assert qt["blocks"]["w"].q.shape == (2, 8, 16)
    assert qt["blocks"]["w"].scale.shape == (2, 16)
    resident, f32_eq = quantized_bytes(qt)
    assert resident < f32_eq
    assert tree_is_quantized(qt) and not tree_is_quantized(tree)


def test_quantize_tree_mode_none_and_unknown():
    tree = _tiny_tree()
    assert quantize_tree(tree, "none") is tree
    with pytest.raises(ValueError, match="int8"):
        quantize_tree(tree, "int4")


def test_router_leaves_stay_f32():
    w = jax.random.normal(KEY, (8, 4), jnp.float32)
    assert not default_quantizable("blocks/moe/router", w)
    assert default_quantizable("lm_head/w", w)


def test_zero_and_denormal_columns_roundtrip_exact():
    w = jax.random.normal(KEY, (32, 4), jnp.float32).at[:, 1].set(0.0)
    w = w.at[:, 2].set(1e-42)        # denormal column
    ql = quantize_leaf(w)
    back = np.asarray(ql.dequantize())
    assert np.all(back[:, 1] == 0.0)
    assert np.all(np.abs(back[:, 2]) <= 1e-40)   # flushed to ~0, no NaNs
    assert not np.any(np.isnan(back))


def test_outlier_column_does_not_poison_neighbors():
    w = jax.random.normal(KEY, (64, 4), jnp.float32) * 0.01
    w = w.at[:, 3].mul(1e4)          # one outlier column
    ql = quantize_leaf(w)
    err = np.abs(np.asarray(ql.dequantize()) - np.asarray(w))
    scale = np.asarray(ql.scale)
    # per-channel scales: each column's error is bounded by ITS scale
    for j in range(4):
        assert err[:, j].max() <= 0.5 * scale[j] * (1 + 1e-5) + 1e-9


def test_take_rows_matches_full_dequant():
    table = jax.random.normal(KEY, (32, 8), jnp.float32) * 0.1
    qt = quantize_leaf(table)
    ids = jnp.asarray([0, 5, 31, 5])
    np.testing.assert_array_equal(
        np.asarray(take_rows(qt, ids)),
        np.asarray(qt.dequantize()[ids]))
    # plain arrays pass through
    np.testing.assert_array_equal(np.asarray(take_rows(table, ids)),
                                  np.asarray(table[ids]))


def test_add_scaled_z_writes_delta_with_the_leafs_own_salt():
    """The z-field of a quantized leaf must be its f32 counterpart's:
    salt from the leaf path (never .../q), update landing in delta."""
    tree = _tiny_tree()
    qt = with_delta(quantize_tree(tree))
    seed, coeff = jnp.uint32(7), 0.25
    up_q = add_scaled_z(qt, seed, coeff)
    up_f = add_scaled_z(tree, seed, coeff)
    for path, want in (("a/w", up_f["a"]["w"] - tree["a"]["w"]),
                       ("blocks/w", up_f["blocks"]["w"]
                        - tree["blocks"]["w"])):
        node = up_q
        for part in path.split("/"):
            node = node[part]
        np.testing.assert_allclose(np.asarray(node.delta), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
    # frozen (delta-less) leaves pass through untouched
    frozen = add_scaled_z(quantize_tree(tree), seed, coeff)
    assert frozen["a"]["w"].delta is None
    np.testing.assert_array_equal(np.asarray(frozen["a"]["w"].q),
                                  np.asarray(qt["a"]["w"].q))


def test_weight_decay_folds_into_delta_and_preserves_pow2_scales():
    """Weight decay must never touch q or scale (a decayed scale is no
    longer a power of two, which would break the exact-product property
    the atol=0 parity rests on): (q*s + d)(1-c) folds into the delta."""
    from repro.core.engine import _decay

    tree = _tiny_tree()
    qt = with_delta(quantize_tree(tree))
    qt["a"]["w"] = dataclasses.replace(
        qt["a"]["w"], delta=qt["a"]["w"].delta + 0.5)
    wd = jnp.float32(0.125)
    dec = _decay(qt, wd)
    lf = dec["a"]["w"]
    np.testing.assert_array_equal(np.asarray(lf.q),
                                  np.asarray(qt["a"]["w"].q))
    np.testing.assert_array_equal(np.asarray(lf.scale),
                                  np.asarray(qt["a"]["w"].scale))
    np.testing.assert_allclose(
        np.asarray(lf.dequantize_f32()),
        np.asarray(qt["a"]["w"].dequantize_f32()) * (1.0 - 0.125),
        rtol=1e-6, atol=1e-7)
    # frozen (delta-less) leaves pass through decay untouched
    froz = _decay(quantize_tree(tree), wd)["a"]["w"]
    assert froz.delta is None
    np.testing.assert_array_equal(np.asarray(froz.scale),
                                  np.asarray(qt["a"]["w"].scale))


def test_lru_budget_charges_only_per_user_delta_over_quantized_base():
    """Materialized trees alias the base's int8 values/scales by
    reference; the cache budget must charge only the per-user f32
    deltas (+ unquantized leaves), or hot users evict over phantom
    bytes of the shared base."""
    base = quantize_tree(_tiny_tree())
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    store = AdapterStore(base, cfg)
    store.put("u", [{"step": 0, "seed": 5, "gs": [0.1, -0.1],
                     "lr": 1e-2, "eps": 1e-3}])
    mat = store.materialize("u")
    want = sum(
        (l.delta.nbytes if is_quantized(l) else l.nbytes)
        for l in jax.tree_util.tree_leaves(mat, is_leaf=is_quantized))
    assert store.cached_bytes() == want
    # the shared base's int8/scale bytes are NOT in the charge
    q_bytes = sum(l.q.nbytes + l.scale.nbytes
                  for l in jax.tree_util.tree_leaves(
                      mat, is_leaf=is_quantized) if is_quantized(l))
    assert store.cached_bytes() < want + q_bytes


def test_quantized_leaf_scan_slices_scale_with_values():
    """lax.scan over a stacked QuantizedLeaf must slice q, scale, and
    delta together (the runtime's layer-scan contract)."""
    ql = with_delta(quantize_leaf(
        jax.random.normal(KEY, (3, 8, 16), jnp.float32)))

    def body(c, leaf):
        assert leaf.q.shape == (8, 16)
        assert leaf.scale.shape == (16,)
        assert leaf.delta.shape == (8, 16)
        return c, jnp.sum(leaf.dequantize_f32())

    _, sums = jax.lax.scan(body, 0, ql)
    np.testing.assert_allclose(
        np.asarray(sums),
        np.asarray(jnp.sum(ql.dequantize_f32(), axis=(1, 2))), rtol=1e-5)


# ---------------------------------------------------------------------------
# fused kernel parity (quantized zo_matmul / zo_add vs dequantize-then-op)

MM_SHAPES = [(8, 128, 128), (16, 96, 160), (7, 33, 130)]


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("coeff", [0.01, -0.01])
@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_quantized_zo_matmul_matches_dequant_then_matmul(mkn, dist, coeff):
    m, k, n = mkn
    x = jax.random.normal(KEY, (m, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n),
                          jnp.float32) * 0.1
    ql = quantize_leaf(w)
    got = ops.zo_matmul(x, ql.q, 7, 123, coeff, dist=dist, scale=ql.scale)
    want = ops.zo_matmul(x, ql.dequantize(), 7, 123, coeff, dist=dist)
    # atol tied to the scale: k accumulations of values quantized to
    # multiples of scale/127 -- identical tiles, so only roundoff is left
    atol = float(np.max(ql.scale)) * k * 1e-6 + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=atol)


@pytest.mark.parametrize("dist", ["rademacher", "gaussian"])
@pytest.mark.parametrize("coeff", [0.01, -0.01])
def test_quantized_zo_add_matches_dequant_plus_z(dist, coeff):
    w = jax.random.normal(KEY, (64, 256), jnp.float32) * 0.1
    ql = quantize_leaf(w)
    got = ops.zo_add(ql.q, 7, 123, coeff, dist=dist, scale=ql.scale)
    z = zrng.z_field(jnp.uint32(7), 123, w.shape, dist=dist)
    want = ql.dequantize() + jnp.float32(coeff) * z
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_ctx_matmul_kernel_path_matches_jnp_fallback():
    """PerturbCtx.matmul over an aligned quantized leaf: the fused
    Pallas kernel (dequant in VMEM) vs the jnp transient."""
    w = jax.random.normal(KEY, (64, 128), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 64),
                          jnp.float32) * 0.1
    ql = quantize_leaf(w)
    for coeff in (1e-3, -1e-3):
        kctx = PerturbCtx(seed=jnp.uint32(5), coeff=jnp.float32(coeff),
                          use_kernel=True, prefix="lm_head")
        jctx = dataclasses.replace(kctx, use_kernel=False)
        np.testing.assert_allclose(np.asarray(kctx.matmul(x, ql)),
                                   np.asarray(jctx.matmul(x, ql)),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# serving: adapters / checkpoints over a quantized base


def _quant_base(seed=1):
    return quantize_tree(_tiny_tree(seed))


def _qloss(p, _):
    return (jnp.sum(deq(p["a"]["w"]).astype(jnp.float32) ** 2) * 1e-3
            + jnp.sum(deq(p["blocks"]["w"]).astype(jnp.float32) ** 2) * 1e-3
            + jnp.sum(p["b"] ** 2) * 1e-3)


def test_adapter_materialize_matches_checkpoint_restore_quantized(tmp_path):
    """AdapterStore.materialize over an int8 base must be bit-identical
    to CheckpointManager.restore over the same base -- the no-format-
    change contract of the quantized runtime."""
    base = _quant_base()
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    strat = build_strategy("vmapdir", "sgd")
    mgr = CheckpointManager(str(tmp_path), mezo_cfg=cfg, snapshot_every=4,
                            update_rule=strat.update)
    state = strat.init_state(with_delta(base), cfg)
    for step in range(9):
        state, aux = strat.step(_qloss, state, None, jnp.uint32(step), cfg)
        mgr.on_step(step, state, aux)

    like = strat.init_state(with_delta(base), cfg)
    restored, nxt = CheckpointManager(
        str(tmp_path), mezo_cfg=cfg, snapshot_every=4,
        update_rule=strat.update).restore(like)
    assert nxt == 9

    store = AdapterStore(base, cfg)
    store.import_checkpoint("u", str(tmp_path))
    mat = store.materialize("u")
    for a, b, live in zip(jax.tree.leaves(mat),
                          jax.tree.leaves(restored.params),
                          jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(live))
    # the int8 base itself never moved
    np.testing.assert_array_equal(np.asarray(mat["a"]["w"].q),
                                  np.asarray(base["a"]["w"].q))


def test_adapter_int8_delta_compaction_over_quantized_base():
    base = _quant_base()
    cfg = MezoConfig(eps=1e-3, lr=1e-2, n_directions=2)
    store = AdapterStore(base, cfg)
    store.put("u", [{"step": t, "seed": 11 + t, "gs": [0.3, -0.2],
                     "lr": 1e-2, "eps": 1e-3} for t in range(4)])
    mat = store.materialize("u")
    compact = AdapterStore(base, cfg)
    compact.put_delta("u", store.export_delta("u"))
    approx = compact.materialize("u")
    for a, b, bb in zip(jax.tree.leaves(mat, is_leaf=is_quantized),
                        jax.tree.leaves(approx, is_leaf=is_quantized),
                        jax.tree.leaves(base, is_leaf=is_quantized)):
        av = a.dequantize_f32() if is_quantized(a) else a
        bv = b.dequantize_f32() if is_quantized(b) else b
        bbv = bb.dequantize_f32() if is_quantized(bb) else bb
        # one int8 roundtrip of the (mat - base) delta per leaf
        d = np.abs(np.asarray(av, np.float32) - np.asarray(bbv, np.float32))
        np.testing.assert_allclose(np.asarray(bv, np.float32),
                                   np.asarray(av, np.float32),
                                   atol=float(d.max()) / 127.0 + 1e-7)


def test_int8_helpers_are_the_single_quant_copy():
    """The dedup satellite: compression.py re-exports optim/quant.py's
    helpers, so delta compaction bytes are unchanged by construction."""
    from repro.optim import quant
    assert compression.int8_quantize is quant.int8_quantize
    assert compression.int8_dequantize is quant.int8_dequantize


def test_dequantize_tree_passthrough_and_effective_values():
    tree = _tiny_tree()
    qt = quantize_tree(tree)
    dq = dequantize_tree(qt)
    assert not tree_is_quantized(dq)
    np.testing.assert_array_equal(np.asarray(dq["a"]["w"]),
                                  np.asarray(qt["a"]["w"].dequantize()))
    # plain leaves and plain trees pass through by identity
    assert dq["b"] is qt["b"]


# ---------------------------------------------------------------------------
# hypothesis property suites (auto-skip without the optional dep; the
# guard is per-section so the deterministic tests above ALWAYS run)

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=25, deadline=None)

    @given(rows=st.integers(1, 48), cols=st.integers(1, 8),
           seed=st.integers(0, 2**31 - 1),
           log_mag=st.floats(-30.0, 20.0))
    @settings(**SETTINGS)
    def test_roundtrip_error_bounded_by_half_scale(rows, cols, seed,
                                                   log_mag):
        """|dequant(quant(w)) - w| <= scale/2 per channel, for
        magnitudes from denormal-adjacent to huge."""
        w = (np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                          (rows, cols)))
             * np.exp(log_mag)).astype(np.float32)
        ql = quantize_leaf(jnp.asarray(w))
        err = np.abs(np.asarray(ql.dequantize()) - w)
        # 0.5*scale from rounding plus a few ulps of f32 div/mul roundoff
        bound = 0.5 * np.asarray(ql.scale)[None, :] * (1 + 1e-4) + 1e-30
        assert np.all(err <= bound), (err.max(), bound.max())

    @given(rows=st.integers(2, 32), cols=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_per_channel_scale_is_pow2_absmax_over_contraction_axis(
            rows, cols, seed):
        """scale = absmax/127 over axis -2, rounded UP to a power of two
        (exactness contract: q*scale must be exact in f32) -- so within
        [1x, 2x] of the optimal absmax scale, and exactly 2^k."""
        w = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                         (rows, cols)), np.float32)
        ql = quantize_leaf(jnp.asarray(w))
        scale = np.asarray(ql.scale)
        absmax = np.max(np.abs(w), axis=0)
        lo = absmax / 127.0
        assert np.all(scale >= lo * (1 - 1e-6))
        assert np.all(scale <= np.maximum(2.0 * lo, 1.0) * (1 + 1e-6))
        mant, _ = np.frexp(scale)
        assert np.all(mant == 0.5)      # exactly a power of two

    @pytest.mark.slow
    @given(m=st.integers(1, 16), k=st.integers(1, 96),
           n=st.integers(1, 144), seed=st.integers(0, 2**31 - 1),
           dist=st.sampled_from(["rademacher", "gaussian"]),
           sign=st.sampled_from([1.0, -1.0]))
    @settings(max_examples=20, deadline=None)
    def test_quantized_zo_matmul_property_parity(m, k, n, seed, dist, sign):
        """Quantized fused kernel == dequantize-then-zo_matmul for
        arbitrary shapes (interpret mode exercises the real tiling),
        ± coeff, both dists -- atol tied to the per-channel scale."""
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), jnp.float32) * 0.1
        w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
        ql = quantize_leaf(w)
        coeff = sign * 0.01
        got = ops.zo_matmul(x, ql.q, seed, 77, coeff, dist=dist,
                            scale=ql.scale)
        want = ops.zo_matmul(x, ql.dequantize(), seed, 77, coeff, dist=dist)
        atol = float(np.max(ql.scale)) * k * 1e-6 + 1e-6
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=atol)

    @pytest.mark.slow
    @given(rows=st.integers(1, 32), cols=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1),
           dist=st.sampled_from(["rademacher", "gaussian"]),
           sign=st.sampled_from([1.0, -1.0]))
    @settings(max_examples=20, deadline=None)
    def test_quantized_zo_add_property_parity(rows, cols, seed, dist, sign):
        w = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols),
                              jnp.float32) * 0.1
        ql = quantize_leaf(w)
        coeff = sign * 0.01
        got = ops.zo_add(ql.q, seed, 99, coeff, dist=dist, scale=ql.scale)
        z = zrng.z_field(jnp.uint32(seed), 99, w.shape, dist=dist)
        want = ql.dequantize() + jnp.float32(coeff) * z
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
