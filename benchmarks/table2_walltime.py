"""Paper Table 2: per-step wall-clock for MeZO vs Adam, x batch size.

The paper found near-parity on the phone (97s vs 74s at bs=8) because the
SoC cannot exploit ZO's parallelism; we reproduce the same comparison on
CPU (reduced model) and additionally benchmark K-direction vmap
parallelism -- the effect the phone could not show (paper Sec 6.3).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (MezoConfig, get_strategy, mezo_step, mezo_step_fused,
                        mezo_step_vmapdir)
from repro.data.synthetic import lm_batch_at, synthetic_lm_corpus
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, grad_train_step


def param_sweeps_per_step(strategy: str, k: int) -> int:
    """Full parameter-sweep passes per step, beyond the shared seed-replay
    update: the sequential walk pays perturb / counter-perturb / restore
    (3 per direction); vmapdir pays one transient perturbed copy per side
    (2 per direction); the fused perturbed forward pays none -- z is
    applied inside the matmul tiles."""
    return {"mezo": 3 * k, "mezo_vmapdir": 2 * k, "mezo_fused": 0}[strategy]


def _time_steps(fn, n=5):
    fn(0)  # compile
    t0 = time.perf_counter()
    for t in range(1, n + 1):
        fn(t)
    return (time.perf_counter() - t0) / n * 1e6  # us/step


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config("roberta-large").reduced(n_layers=2, d_model=128,
                                              d_ff=256, vocab=256,
                                              n_classes=0, family="dense",
                                              pos="rope", norm="rmsnorm",
                                              act="swiglu", causal=True)
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    stream = synthetic_lm_corpus(64 * 40 * 33, cfg.vocab, 0)
    rows, table = [], {}

    bs_k = 1            # directions per step in the bs arms below
    for bs in (8, 64):
        def batch_at(t):
            return {k: jnp.asarray(v) for k, v in
                    lm_batch_at(t, bs, 32, cfg.vocab, stream).items()}

        # mezo
        p = jax.tree.map(jnp.copy, params0)
        mcfg = MezoConfig(eps=1e-3, lr=1e-5, n_directions=bs_k)
        state = {"p": p}

        def mezo_fn(t):
            state["p"], _ = mezo_step(model.loss, state["p"], batch_at(t),
                                      jnp.uint32(t), mcfg)
            jax.block_until_ready(jax.tree.leaves(state["p"])[0])
        us = _time_steps(mezo_fn)
        rows.append((f"table2/mezo/bs{bs}", us,
                     f"{param_sweeps_per_step('mezo', mcfg.n_directions)} "
                     f"param sweeps/step"))
        table[f"mezo/bs{bs}"] = us

        # mezo fused: perturbed forward, no perturb/restore sweeps. NB on
        # CPU this times the transient-jnp fallback (use_kernel=False --
        # interpret-mode Pallas would benchmark the Python interpreter);
        # the in-tile zo_matmul path engages on TPU via use_kernel=True
        p = jax.tree.map(jnp.copy, params0)
        fstate = {"p": p}
        fcfg = MezoConfig(eps=1e-3, lr=1e-5, n_directions=bs_k,
                          use_kernel=jax.default_backend() == "tpu")

        def fused_fn(t):
            fstate["p"], _ = mezo_step_fused(model.loss, fstate["p"],
                                             batch_at(t), jnp.uint32(t), fcfg)
            jax.block_until_ready(jax.tree.leaves(fstate["p"])[0])
        us = _time_steps(fused_fn)
        rows.append((f"table2/mezo_fused/bs{bs}", us,
                     f"{param_sweeps_per_step('mezo_fused', mcfg.n_directions)}"
                     f" param sweeps/step (jnp fallback; kernel path is "
                     f"TPU-only)"))
        table[f"mezo_fused/bs{bs}"] = us

        # adam
        p = jax.tree.map(jnp.copy, params0)
        astate = {"p": p, "s": adam_init(p)}

        def adam_fn(t):
            astate["p"], astate["s"], _ = grad_train_step(
                model.loss, astate["p"], batch_at(t), astate["s"],
                AdamConfig())
            jax.block_until_ready(jax.tree.leaves(astate["p"])[0])
        us = _time_steps(adam_fn)
        rows.append((f"table2/adam/bs{bs}", us, ""))
        table[f"adam/bs{bs}"] = us

    # K-direction scaling (the parallelism the phone couldn't exploit)
    for k in (1, 4):
        p = jax.tree.map(jnp.copy, params0)
        kcfg = MezoConfig(eps=1e-3, lr=1e-5, n_directions=k)
        st = {"p": p}

        def kfn(t):
            st["p"], _ = mezo_step_vmapdir(model.loss, st["p"], batch_at(t),
                                           jnp.uint32(t), kcfg)
            jax.block_until_ready(jax.tree.leaves(st["p"])[0])
        us = _time_steps(kfn, n=3)
        rows.append((f"table2/mezo_vmapdir/K{k}", us,
                     "directions evaluated concurrently"))
        table[f"mezo_vmapdir/K{k}"] = us

    # chunked multi-step scan: the engine's run_chunk folds CHUNK steps
    # into one lax.scan dispatch, amortizing per-step launch overhead
    # (seed derivation inside the scan matches the Trainer's, so the
    # replay log of a chunked run is interchangeable with a stepwise one)
    bs, chunk = 8, 8
    ccfg = MezoConfig(eps=1e-3, lr=1e-5, n_directions=bs_k)
    strat = get_strategy("mezo")
    cstate = {"s": strat.init_state(jax.tree.map(jnp.copy, params0), ccfg)}

    def stacked_batches(t):
        bl = [lm_batch_at(t * chunk + i, bs, 32, cfg.vocab, stream)
              for i in range(chunk)]
        return {k: jnp.stack([jnp.asarray(b[k]) for b in bl])
                for k in bl[0]}

    def chunk_fn(t):
        cstate["s"], _ = strat.run_chunk(model.loss, cstate["s"],
                                         stacked_batches(t), jnp.uint32(0),
                                         ccfg)
        jax.block_until_ready(jax.tree.leaves(cstate["s"].params)[0])

    us_per_step = _time_steps(chunk_fn, n=3) / chunk
    sps = 1e6 / us_per_step
    rows.append((f"table2/mezo_chunked/bs{bs}", us_per_step,
                 f"{chunk}-step lax.scan chunk; {sps:.1f} steps/s "
                 f"(vs {1e6 / table[f'mezo/bs{bs}']:.1f} steps/s stepwise)"))
    table[f"mezo_chunked/bs{bs}"] = us_per_step
    table["chunked"] = {"chunk_steps": chunk, "steps_per_sec": sps,
                        "stepwise_steps_per_sec": 1e6 / table[f"mezo/bs{bs}"]}

    # per-family fused arms: the block-registry runtime extends the fused
    # perturbed forward to hybrid / rwkv6 / encdec (previously a transient
    # materialize fallback), so every family now has a 0-sweep step; time
    # it against vmapdir (the old fallback's memory/compute profile)
    for arch in ("jamba-v0.1-52b", "rwkv6-7b", "whisper-base"):
        fcfg2 = get_config(arch).reduced()
        fmodel = build_model(fcfg2)
        fparams = fmodel.init(jax.random.PRNGKey(0))
        fstream = synthetic_lm_corpus(8 * 40 * 33, fcfg2.vocab, 0)

        def fam_batch(t):
            b = {k: jnp.asarray(v) for k, v in
                 lm_batch_at(t, 8, 32, fcfg2.vocab, fstream).items()}
            if fcfg2.family == "encdec":
                b["enc_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(t), (8, fcfg2.enc_len, fcfg2.d_model))
            return b

        famcfg = MezoConfig(eps=1e-3, lr=1e-5, n_directions=1)
        for strat_name, step_fn in (("fused", mezo_step_fused),
                                    ("vmapdir", mezo_step_vmapdir)):
            fs = {"p": jax.tree.map(jnp.copy, fparams)}

            def fam_fn(t, fs=fs, step_fn=step_fn):
                fs["p"], _ = step_fn(fmodel.loss, fs["p"], fam_batch(t),
                                     jnp.uint32(t), famcfg)
                jax.block_until_ready(jax.tree.leaves(fs["p"])[0])

            us = _time_steps(fam_fn, n=3)
            rows.append((f"table2/family_{strat_name}/{arch}", us,
                         f"{fcfg2.family} fused ZO arm"
                         if strat_name == "fused" else
                         f"{fcfg2.family} transient-copy baseline"))
            table[f"family/{arch}/{strat_name}"] = us

    # K of the bs arms above (counts scale linearly in K)
    table["param_sweeps_per_step"] = {
        s: param_sweeps_per_step(s, bs_k)
        for s in ("mezo", "mezo_vmapdir", "mezo_fused")}
    with open(os.path.join(out_dir, "table2_walltime.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows
