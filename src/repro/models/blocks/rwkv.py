"""RWKV-6 blocks: time-mix (the WKV linear-attention mixer) and
channel-mix (the squared-ReLU FFN). Both carry a token-shift buffer;
time-mix additionally carries the (H, hd, hd) WKV accumulator. The
full-sequence scan and the per-token cell are the same recurrence, so
prefill and decode share one implementation."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import rwkv6 as R
from repro.models.blocks.base import BlockType, register_block


def _tm_apply(cfg, p, x, rc, ctx=None):
    y, _ = R.timemix_apply(cfg, p, x, ctx=ctx)
    return y, jnp.float32(0.0)


def _tm_state_spec(cfg, bsz, max_len, dtype):
    h, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {"state": ((bsz, h, hd, hd), jnp.float32),
            "x_prev": ((bsz, 1, cfg.d_model), dtype)}


def _tm_step(cfg, p, state, x, rc, ctx=None):
    y, (st, xl) = R.timemix_apply(cfg, p, x, state=state["state"],
                                  x_prev=state["x_prev"])
    return y, {"state": st, "x_prev": xl}


def _cm_apply(cfg, p, x, rc, ctx=None):
    y, _ = R.channelmix_apply(cfg, p, x, ctx=ctx)
    return y, jnp.float32(0.0)


def _cm_state_spec(cfg, bsz, max_len, dtype):
    return {"x_prev": ((bsz, 1, cfg.d_model), dtype)}


def _cm_step(cfg, p, state, x, rc, ctx=None):
    y, xl = R.channelmix_apply(cfg, p, x, x_prev=state["x_prev"])
    return y, {"x_prev": xl}


RWKV_TIMEMIX = register_block(BlockType(
    name="rwkv_timemix", init=R.timemix_init, apply=_tm_apply,
    state_spec=_tm_state_spec, prefill=_tm_step, decode_step=_tm_step))
RWKV_CHANNELMIX = register_block(BlockType(
    name="rwkv_channelmix", init=R.channelmix_init, apply=_cm_apply,
    state_spec=_cm_state_spec, prefill=_cm_step, decode_step=_cm_step))
