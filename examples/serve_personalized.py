"""End-to-end personalized-LLM flow (the paper's motivating scenario),
multi-user edition:

  1. fine-tune TWO "users" on their own (synthetic) private data with
     MeZO -- same shared base weights, different data,
  2. export each user's fine-tune as a ZO adapter: the replay log alone,
     a few KB of (seed, gs) scalars instead of a parameter tree,
  3. serve interleaved per-user requests from ONE engine instance --
     adapters materialized on demand (base + replay), fused prefill,
     continuous-batching decode.

  PYTHONPATH=src python examples/serve_personalized.py
"""

import shutil

import jax
import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batches
from repro.models import build_model
from repro.runtime import Trainer, TrainerConfig
from repro.serve import AdapterStore, Request, ServeEngine, tree_bytes

MZ = MezoConfig(eps=1e-2, lr=5e-3, n_directions=4)
USERS = {"alice": 11, "bob": 23}          # user -> private-data seed


def finetune(cfg, user: str, data_seed: int, ckpt: str):
    shutil.rmtree(ckpt, ignore_errors=True)
    # vmapdir estimator => pristine base point => the replay log is a
    # bit-exact reconstruction of the fine-tune (walk would drift ~1e-5)
    tc = TrainerConfig(optimizer="mezo-parallel", mezo=MZ, n_steps=30,
                      ckpt_dir=ckpt, snapshot_every=15, log_every=10, seed=0)
    tr = Trainer(cfg, tc, lm_batches(8, 32, cfg.vocab, seed=data_seed))
    tr.train()
    print(f"[{user}] fine-tuned on private data: "
          f"loss {tr.losses[0]:.3f} -> {tr.losses[-1]:.3f}")


def main():
    cfg = get_config("gemma-2b").reduced()
    ckpts = {u: f"/tmp/pocketllm_personalized_{u}" for u in USERS}
    for user, seed in USERS.items():
        finetune(cfg, user, seed, ckpts[user])

    # fresh "serving process": shared base weights + per-user scalar logs
    base = build_model(cfg).init(jax.random.PRNGKey(0))   # Trainer's seed=0
    store = AdapterStore(base, MZ)
    for user in USERS:
        ad = store.import_checkpoint(user, ckpts[user])
        print(f"[{user}] adapter: {ad.n_steps} steps, {ad.nbytes} B "
              f"(base tree: {tree_bytes(base)} B)")
    deltas = {u: np.max(np.abs(np.asarray(jax.tree.leaves(
        store.materialize(u))[0], np.float32)
        - np.asarray(jax.tree.leaves(base)[0], np.float32)))
        for u in USERS}
    assert all(d > 0 for d in deltas.values()), deltas   # really fine-tuned

    engine = ServeEngine(cfg, store, n_slots=2, max_len=32, seed=0)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (6, 8), dtype=np.int32)
    users = [u for _, u in zip(range(6), 3 * list(USERS))]
    rids = {engine.submit(Request(prompt=prompts[i], max_new=6, user=u)): u
            for i, u in enumerate(users)}
    completions = engine.run()          # 6 requests through 2 slots:
    served = {}                         # admission happens mid-flight
    for c in completions:
        assert c.tokens.shape == (6,) and rids[c.rid] == c.user
        served.setdefault(c.user, []).append(c.rid)
        print(f"[serve] rid={c.rid} user={c.user}: {c.tokens.tolist()}")
    assert set(served) == set(USERS), served
    st = engine.stats
    print(f"[serve] interleaved {len(completions)} requests from "
          f"{len(served)} adapters in one engine | prefill "
          f"{st.prefill_tps:.0f} tok/s | decode {st.decode_tps:.0f} tok/s | "
          f"adapter cache: {store.stats['misses']} materializations, "
          f"{store.stats['hits']} hits")
    print("OK: fine-tune x2 -> export ZO adapters -> serve interleaved")


if __name__ == "__main__":
    main()
