"""Short-window verify attention over a paged KV cache (Pallas TPU +
jnp reference) -- the multi-token-query sibling of flash_decode.

Speculative decoding scores a *window* of W = k+1 candidate tokens per
slot in one dispatch: query offset w of slot b sits at logical position
``pos[b] + w`` and may attend to every cached position ``<= pos[b] + w``
-- the page-table gather of flash-decoding plus causal masking *inside*
the window. The window's own K/V has already been scattered into the
slot's pages by the caller (the verifier overwrites the draft's entries
before reading), so the kernel is pure page reads: no separate in-window
attention pass, and speculation adds zero KV HBM.

Layout: q (B, W, H, hd) -- W candidate tokens per slot; k/v pools
(n_pages, page_size, KV, hd); pages (B, n_live) physical page ids;
pos (B,) each slot's first window position. Grid (B, KV, W, n_live),
pages innermost so the online-softmax partials (acc, m, l) in VMEM
scratch reduce over pages exactly as flash_decode does -- one scratch
lifetime per (slot, kv head, window offset).

``verify_attn_ref`` is the pure-jnp oracle and the non-TPU hot path; at
W=1 it degenerates to the same math as ``paged_attn_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import check_head_dim

_NEG_INF = -1e30


def _verify_kernel(pages_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, ps, n_live, scale):
    bi = pl.program_id(0)
    wi = pl.program_id(2)
    pp = pl.program_id(3)

    @pl.when(pp == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # window offset w attends through position pos + w: the draft tokens
    # earlier in the window are visible (causal inside the window), the
    # later ones and the slot's dead tail are not
    pos = pos_ref[bi] + wi
    live = pp * ps <= pos

    @pl.when(live)
    def _():
        q = q_ref[0, 0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (ps, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        k_pos = pp * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(k_pos <= pos, s, _NEG_INF)             # (G, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(pp == n_live - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_verify(q, k_pages, v_pages, pages, pos, *,
                 interpret: bool = False):
    """q: (B, W, H, hd); k/v pools: (NP, ps, KV, hd); pages: (B, n_live)
    int32 physical page ids; pos: (B,) int32 -> (B, W, H, hd).

    Window offset w of slot b reads positions <= pos[b] + w; everything
    later (the rest of the window, the dead tail, trash-page table
    entries) is masked out.
    """
    b, w, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    g = h // kvh
    n_live = pages.shape[1]
    check_head_dim(hd, interpret=interpret, kernel="flash_verify")
    qg = q.reshape(b, w, kvh, g, hd).transpose(0, 2, 1, 3, 4)

    def qmap(bi, kv, wi, pp, pages_ref, pos_ref):
        return (bi, kv, wi, 0, 0)

    def kvmap(bi, kv, wi, pp, pages_ref, pos_ref):
        return (pages_ref[bi, pp], 0, kv, 0)

    kern = functools.partial(_verify_kernel, ps=ps, n_live=n_live,
                             scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # pages, pos
        grid=(b, kvh, w, n_live),
        in_specs=[
            pl.BlockSpec((1, 1, 1, g, hd), qmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
            pl.BlockSpec((1, ps, 1, hd), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, g, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, w, g, hd), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), pos.astype(jnp.int32), qg, k_pages, v_pages)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, w, h, hd)


def verify_attn_ref(q, k_pages, v_pages, pages, pos):
    """jnp oracle / non-TPU hot path: gather the live pages into logical
    order and run masked GQA attention with a per-(slot, offset) limit
    ``k_pos <= pos + w`` -- flash_decode's dead-tail skip plus causal
    masking inside the window, expressed as one 3-D kv_mask."""
    b, w, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    n_live = pages.shape[1]
    kk = k_pages[pages].reshape(b, n_live * ps, kvh, hd)
    vv = v_pages[pages].reshape(b, n_live * ps, kvh, hd)
    qpos = pos[:, None] + jnp.arange(w)[None, :]             # (B, W)
    valid = jnp.arange(n_live * ps)[None, None, :] <= qpos[:, :, None]
    from repro.models.layers import attention
    return attention(q, kk, vv, causal=False, kv_mask=valid, chunk=0)
