"""int8 quantization: the quantized-base runtime + shared int8 helpers.

PocketLLM's headline claim is *memory* (RoBERTa-large in ~4GB, OPT-1.3B
in ~6.5GB on a phone). The fused ZO path already removed the transient
perturbed parameter copy; the remaining lever is the resident base
weights themselves. This module provides the quantized-base
representation the whole stack threads through:

* :class:`QuantizedLeaf` -- one parameter leaf as int8 values plus
  per-channel f32 scales (absmax over the contraction axis ``-2``,
  rounded up to a power of two so ``q*scale`` is exact in f32; a
  ``(K, N)`` projection carries an ``(N,)`` scale and a scan-stacked
  ``(L, K, N)`` leaf an ``(L, N)`` one -- the leading layer axis slices
  through ``lax.scan`` exactly like the values). An optional f32
  ``delta`` carries the accumulated ZO update stream: the int8 base
  stays frozen; training writes only the additive side (the
  derivative-free analogue of PAE MobiLLM's additive deltas).
* :func:`quantize_tree` -- one-shot base quantization of a param
  pytree (deterministic round-to-nearest: the quantized base is a pure
  function of the f32 base, so every host/restart agrees bit-for-bit).
* use-site helpers (:func:`deq`, :func:`take_rows`,
  :func:`dequantize_tree`) that pass plain arrays through untouched, so
  the model code has ONE path for quantized and full-precision bases.

Seed-replay contract: a :class:`QuantizedLeaf` is *atomic* for every
salt/path computation (``core.perturb`` flattens with
``is_leaf=is_quantized``), so the z-field of a quantized leaf is
bit-identical to its f32 counterpart's -- replay logs, adapters, and
checkpoints move freely between quantized and full-precision bases.

The per-tensor stochastic-rounding helpers (``int8_quantize`` /
``int8_dequantize``) used by gradient compression
(``optim/compression.py``) and adapter delta compaction
(``serve/adapters.py``) live here as the single copy; deterministic
per-channel quantization (the base) and stochastic per-tensor
quantization (wire/delta compression) are deliberately different codes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as zrng

PyTree = Any

#: supported --quant modes ("none" is the f32 passthrough)
QUANT_MODES = ("none", "int8")


def check_quant_mode(mode: str) -> str:
    """Validate a quantization mode name (mirrors the engine's
    estimator/update registry errors)."""
    if mode not in QUANT_MODES:
        raise ValueError(
            f"unknown quantization mode {mode!r}; supported modes: "
            f"{list(QUANT_MODES)}")
    return mode


# ---------------------------------------------------------------------------
# per-tensor stochastic int8 (gradient compression / delta compaction)
# -- moved verbatim from optim/compression.py; that module and
# serve/adapters.py now import the single copy from here.


def int8_quantize(g: jnp.ndarray, seed=jnp.uint32(0x51CA)):
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-30
    x = g.astype(jnp.float32) / scale
    # stochastic rounding via the same hash field used for ZO noise
    u = (zrng._coord_hash(seed, 0xC0DE, g.shape) >> 8).astype(jnp.float32) \
        * (1.0 / 16777216.0)
    q = jnp.clip(jnp.floor(x + u), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# the quantized-base leaf


@dataclasses.dataclass(frozen=True)
class QuantizedLeaf:
    """One frozen int8 base leaf (+ optional f32 adapter delta).

    Effective weight: ``q * expand(scale) (+ delta)``. ``scale`` is the
    per-channel power-of-two absmax scale over axis ``-2`` (the
    contraction axis of a matmul weight, see :func:`quantize_leaf`),
    shape ``shape[:-2] + (shape[-1],)``; ``delta`` is
    ``None`` (frozen serving base) or a full-shape f32 array carrying
    the accumulated ZO updates (``core.perturb.add_scaled_z`` writes
    here; the int8 values never change).

    Registered as a pytree whose children are ``(q, scale, delta)`` so
    it flows through jit / scan / checkpoint IO; perturbation-path code
    treats it atomically via ``is_leaf=is_quantized`` so salts bind to
    the *leaf's* pytree path, never to ``.../q``.
    """
    q: Any                          # int8, the original leaf shape
    scale: Any                      # f32, shape[:-2] + (shape[-1],)
    delta: Any = None               # f32 accumulated update, or None
    orig_dtype: Any = jnp.float32   # dtype the f32 path would carry

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        """The *logical* dtype (what a full-precision base would be)."""
        return jnp.dtype(self.orig_dtype)

    @property
    def nbytes(self) -> int:
        n = self.q.nbytes + self.scale.nbytes
        return n + (self.delta.nbytes if self.delta is not None else 0)

    def base_f32(self):
        """The frozen base alone, ``q*scale``, in f32 (exact: int8
        times a power-of-two scale)."""
        return self.q.astype(jnp.float32) * _expand(self.scale)

    def dequantize_f32(self):
        """q*scale (+ delta) in f32 -- the exact arithmetic every use
        site (fused or materialized) shares, so parity is bit-for-bit."""
        w = self.base_f32()
        if self.delta is not None:
            w = w + self.delta.astype(jnp.float32)
        return w

    def dequantize(self):
        """Effective weight in the logical dtype."""
        return self.dequantize_f32().astype(self.dtype)


jax.tree_util.register_pytree_with_keys(
    QuantizedLeaf,
    lambda l: (((jax.tree_util.DictKey("q"), l.q),
                (jax.tree_util.DictKey("scale"), l.scale),
                (jax.tree_util.DictKey("delta"), l.delta)),
               jnp.dtype(l.orig_dtype)),
    lambda dt, c: QuantizedLeaf(q=c[0], scale=c[1], delta=c[2],
                                orig_dtype=dt),
)


def is_quantized(x) -> bool:
    return isinstance(x, QuantizedLeaf)


def _expand(scale):
    """Broadcast a per-channel scale back over the reduced axis -2."""
    return scale[..., None, :]


def quantize_leaf(w, with_delta: bool = False) -> QuantizedLeaf:
    """Deterministic symmetric per-channel int8 quantization of one
    rank>=2 leaf. Round-to-nearest (not stochastic): the base must be a
    reproducible function of the f32 weights. Zero / denormal channels
    get scale 1.0 so they roundtrip to exact zeros instead of NaNs.

    Scales are the per-channel absmax/127 rounded UP to a power of two:
    ``q * scale`` is then *exact* in f32 (int8 times 2^k), which is what
    makes the fused dequant+perturb bit-identical to a materialized
    ``dequant(Wq) + c*z`` under any compiler contraction -- XLA may fuse
    the dequant multiply into an FMA with the perturbation add, and with
    an exact product the contracted and uncontracted roundings agree.
    Cost: quantization error up to 2x the optimal absmax scaling (still
    <= scale/2 for the chosen scale)."""
    wf = jnp.asarray(w).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=-2)
    pow2 = jnp.exp2(jnp.ceil(jnp.log2(absmax / 127.0)))
    scale = jnp.where((absmax > 0) & (pow2 > 0) & jnp.isfinite(pow2),
                      pow2, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(wf / _expand(scale)), -127, 127).astype(jnp.int8)
    delta = jnp.zeros(wf.shape, jnp.float32) if with_delta else None
    return QuantizedLeaf(q=q, scale=scale, delta=delta,
                         orig_dtype=jnp.dtype(w.dtype))


def default_quantizable(path: str, leaf) -> bool:
    """Which leaves the one-shot base quantization touches.

    Matrix-shaped floating leaves only: rank >= 2 at top level
    (embeddings, heads), rank >= 3 under a scanned stack scope
    (``*blocks``), where every leaf carries a leading layer axis -- a
    stacked ``(L, d)`` leaf is a per-layer *vector* (norm scale, bias),
    and those are both precision-critical and a rounding error of the
    byte budget. MoE router weights stay f32: top-k routing is
    discrete, so router rounding flips expert assignments instead of
    degrading smoothly.
    """
    if is_quantized(leaf):
        return False
    min_rank = 3 if path.split("/", 1)[0].endswith("blocks") else 2
    if getattr(leaf, "ndim", 0) < min_rank:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    return not path.endswith("router")


def quantize_tree(params: PyTree, mode: str = "int8",
                  with_delta: bool = False, quantizable=None) -> PyTree:
    """One-shot base quantization of a param pytree.

    mode "none" returns the tree untouched (the f32 passthrough the
    trainer's ``--quant none`` resolves to); unknown modes raise the
    registry-style ValueError. ``with_delta=True`` attaches a zero f32
    delta to every quantized leaf -- required for any tree that will be
    *trained* (the update stream lands in the delta; a delta-less base
    is frozen and ``add_scaled_z`` leaves it untouched).
    """
    check_quant_mode(mode)
    if mode == "none":
        return params
    pred = quantizable or default_quantizable
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_quantized)
    out = []
    for path, leaf in leaves:
        ps = _path_str(path)
        out.append(quantize_leaf(leaf, with_delta) if pred(ps, leaf)
                   else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# use-site helpers (pass plain arrays through untouched)


def deq(w):
    """Effective weight of ``w`` -- dequantized if quantized, as-is
    otherwise. The single plain-forward entry point for every use site
    (dense matmuls, convs, einsums)."""
    return w.dequantize() if is_quantized(w) else w


def take_rows_f32(table, ids):
    """Row gather in f32 that never materializes a dequantized table:
    O(rows * cols) work for a quantized ``(R, C)`` leaf, exactly like
    the fused path's ``rng.z_rows`` embedding trick. The single copy of
    the quantized-gather arithmetic -- both the plain forward
    (:func:`take_rows`) and the perturbed one (``PerturbCtx.take``)
    build on it, so they cannot drift apart."""
    if not is_quantized(table):
        return jnp.take(table, ids, axis=0).astype(jnp.float32)
    rows = jnp.take(table.q, ids, axis=0).astype(jnp.float32) * table.scale
    if table.delta is not None:
        rows = rows + jnp.take(table.delta, ids, axis=0)
    return rows


def take_rows(table, ids):
    """Row gather in the table's logical dtype (plain-forward use)."""
    if not is_quantized(table):
        return jnp.take(table, ids, axis=0)
    return take_rows_f32(table, ids).astype(table.dtype)


def dequantize_tree(tree: PyTree) -> PyTree:
    """Transient full-precision view of a (sub)tree -- the generic
    fallback for code that consumes stacked leaves in nonstandard ways
    (MoE sort-based dispatch). Plain trees pass through unchanged."""
    return jax.tree_util.tree_map(deq, tree, is_leaf=is_quantized)


def with_delta(tree: PyTree) -> PyTree:
    """Attach zero f32 deltas to any delta-less quantized leaves, making
    the tree update-capable. The delta must exist *before* the first
    ``add_scaled_z``: the update sweep runs under ``lax.scan``, whose
    carry treedef is fixed, so a leaf cannot grow a delta mid-scan."""
    def ensure(leaf):
        if is_quantized(leaf) and leaf.delta is None:
            return dataclasses.replace(
                leaf, delta=jnp.zeros(leaf.shape, jnp.float32))
        return leaf
    return jax.tree_util.tree_map(ensure, tree, is_leaf=is_quantized)


def tree_is_quantized(tree: PyTree) -> bool:
    return any(is_quantized(l) for l in jax.tree_util.tree_leaves(
        tree, is_leaf=is_quantized))


def quantized_bytes(tree: PyTree):
    """(resident_bytes, f32_equivalent_bytes) of a param tree -- the
    table-1 quant arm's accounting. Resident counts int8 values + f32
    scales (+ deltas if attached); the f32 equivalent counts every
    floating leaf at 4 bytes/element."""
    resident = f32_eq = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_quantized):
        if is_quantized(leaf):
            resident += leaf.nbytes
            f32_eq += 4 * int(np.prod(leaf.shape))
        else:
            resident += leaf.nbytes
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                f32_eq += 4 * int(np.prod(leaf.shape))
            else:
                f32_eq += leaf.nbytes
    return resident, f32_eq
