"""Golden parity suite for the block-registry runtime.

Pins forward logits, loss scalars (plain and perturbed), greedy decode
tokens, and prefill logits of all five families against values captured
at the pre-refactor seed (tests/golden/runtime_parity.json, written by
tests/golden/capture_goldens.py). Any numerical drift in the generic
backbone engine -- block order, norm placement, ctx scoping, cache
layout -- names the family it broke.

Also asserts the contracts the refactors introduced:
  * fused-vs-materialize loss bit-closeness (atol=0 in f32) for the
    families that previously fell back to a transient perturbed copy;
  * the unified StateCache invariant (every leaf (n_layers, B, ...));
  * the quantized-base arms: int8-base logits within a documented
    tolerance of the f32 goldens for every family, and quantized fused
    loss bit-equal (atol=0) to the materialized dequant(Wq)+eps*z loss.

Set REPRO_FAMILY=<family[,family]> to restrict to one family (the CI
family-matrix job does).
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
import capture_goldens as cg  # noqa: E402  (the single source of batch/arch defs)

from repro.configs import get_config            # noqa: E402
from repro.core import PerturbCtx               # noqa: E402
from repro.models import build_model            # noqa: E402
from repro.optim.quant import quantize_tree     # noqa: E402

with open(os.path.join(os.path.dirname(__file__), "golden",
                       "runtime_parity.json")) as f:
    GOLDEN = json.load(f)

_FAM = os.environ.get("REPRO_FAMILY")
ARCHS = [a for a, rec in GOLDEN.items()
         if not _FAM or rec["family"] in _FAM.split(",")]
FUSED_PARITY_ARCHS = [a for a in ARCHS
                      if GOLDEN[a]["family"] in ("hybrid", "ssm", "encdec")]


@pytest.fixture(scope="module")
def captured():
    """Recompute every golden quantity once per run (capture is the
    oracle: same batches, same seeds as the pinned file)."""
    return {arch: cg.capture(arch) for arch in ARCHS}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_init_pinned(arch, captured):
    np.testing.assert_allclose(captured[arch]["param_l1"],
                               GOLDEN[arch]["param_l1"], rtol=1e-6)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_logits_pinned(arch, captured):
    got, want = captured[arch], GOLDEN[arch]
    np.testing.assert_allclose(np.asarray(got["logits_last"]),
                               np.asarray(want["logits_last"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got["logits_mean"], want["logits_mean"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["logits_absum"], want["logits_absum"],
                               rtol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_scalars_pinned(arch, captured):
    got, want = captured[arch], GOLDEN[arch]
    np.testing.assert_allclose(got["loss"], want["loss"],
                               rtol=1e-6, atol=1e-6)
    # the perturbed loss was captured through the OLD materialize
    # fallback (hybrid/ssm/encdec) -- the fused path must reproduce it
    np.testing.assert_allclose(got["loss_perturbed"],
                               want["loss_perturbed"],
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ARCHS)
def test_greedy_decode_pinned(arch, captured):
    assert captured[arch]["greedy_tokens"] == GOLDEN[arch]["greedy_tokens"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_logits_pinned(arch, captured):
    if "prefill_logits_last" not in GOLDEN[arch]:
        pytest.skip("family gained prefill after the golden capture "
                    "(encdec); pinned via the decode-loop parity below")
    np.testing.assert_allclose(
        np.asarray(captured[arch]["prefill_logits_last"]),
        np.asarray(GOLDEN[arch]["prefill_logits_last"]),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", FUSED_PARITY_ARCHS)
def test_fused_loss_bit_equals_materialize(arch):
    """Acceptance: the fused in-place perturbed forward is bit-identical
    (atol=0, f32 accumulation) to evaluating the loss at a transiently
    materialized theta+eps*z -- for exactly the families that used to
    take the materialize fallback."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = cg.make_batch(cfg, jax.random.PRNGKey(1))
    for seed, coeff in ((3, 1e-3), (11, -1e-3)):
        ctx = PerturbCtx(seed=jnp.uint32(seed), coeff=jnp.float32(coeff))
        fused = model.loss(params, batch, perturb=ctx)
        mat = model.loss(ctx.materialize(params), batch)
        np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                      np.asarray(mat, np.float32))


# ---------------------------------------------------------------------------
# quantized-base arms (int8 base, optim/quant.py)

#: documented tolerance of the int8 quantized forward vs the f32
#: goldens: per-channel absmax quantization bounds each weight's error
#: by scale/2 ~ absmax/254 (~0.4% of the channel absmax); measured
#: relative-L2 logit deviation across the five reduced families is
#: 0.9-1.5%, so 5% gives ~3x headroom without masking real breakage.
QUANT_LOGIT_REL_L2 = 0.05


@pytest.mark.parametrize("arch", ARCHS)
def test_quantized_forward_within_tolerance_of_goldens(arch):
    """int8-base forward logits for every family stay within the
    documented relative-L2 tolerance of the pinned f32 goldens."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = cg.make_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = model.forward(quantize_tree(params), batch)
    got = np.asarray(logits[:, -1, :], np.float32)
    want = np.asarray(GOLDEN[arch]["logits_last"], np.float32)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < QUANT_LOGIT_REL_L2, f"{arch}: rel L2 {rel:.4f}"


@pytest.mark.parametrize("arch", ARCHS)
def test_quantized_fused_loss_bit_equals_materialize(arch):
    """Acceptance: the quantized fused loss (dequant + perturbation at
    every use site) is bit-identical (atol=0, f32 accumulation) to the
    loss at a materialized ``dequant(Wq) + eps*z`` copy -- in every
    family, both coefficient signs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    qparams = quantize_tree(model.init(jax.random.PRNGKey(0)))
    batch = cg.make_batch(cfg, jax.random.PRNGKey(1))
    for seed, coeff in ((3, 1e-3), (11, -1e-3)):
        ctx = PerturbCtx(seed=jnp.uint32(seed), coeff=jnp.float32(coeff))
        fused = model.loss(qparams, batch, perturb=ctx)
        mat = model.loss(ctx.materialize(qparams), batch)
        np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                      np.asarray(mat, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_state_cache_layout_uniform(arch):
    """The unified StateCache contract serve/engine.py relies on: every
    leaf is (n_layers, B, ...) -- batch always on axis 1."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    bsz = 3
    cache = model.init_cache(bsz, 16)
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        assert leaf.ndim >= 2 and leaf.shape[1] == bsz, \
            f"{jax.tree_util.keystr(path)}: {leaf.shape}"


def test_encdec_prefill_matches_decode_loop():
    """whisper gained fused prefill in the runtime refactor (the last
    prefill=None gap): one prefill call must equal P decode_step calls,
    logits and cache."""
    if _FAM and "encdec" not in _FAM.split(","):
        pytest.skip("filtered out by REPRO_FAMILY")
    cfg = get_config("whisper-base").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, P = 2, 7
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, P), 0, cfg.vocab)
    cache = model.init_cache(B, P + 4)
    lg = None
    for t in range(P):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
    pf_lg, pf_cache = model.prefill(params, model.init_cache(B, P + 4), toks)
    np.testing.assert_allclose(np.asarray(pf_lg, np.float32),
                               np.asarray(lg, np.float32),
                               rtol=2e-3, atol=2e-3)
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(cache),
            jax.tree_util.tree_leaves_with_path(pf_cache)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=jax.tree_util.keystr(ka))
