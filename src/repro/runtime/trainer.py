"""Fault-tolerant training loop for ZO (MeZO) and gradient (Adam) arms.

Responsibilities: build model + shardings, auto-resume (snapshot + replay
log), per-step straggler masks, metrics, periodic checkpointing. The loop
is deliberately dumb -- all cleverness lives in core/ and checkpoint/ --
so its failure behavior is auditable: any crash between two ``on_step``
calls loses at most the step in flight.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import rng as zrng
from repro.core.mezo import (MezoConfig, mezo_step, mezo_step_fused,
                             mezo_step_vmapdir)
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim.adam import AdamConfig, adam_init, grad_train_step
from repro.runtime.stragglers import StragglerPolicy

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "mezo"          # mezo | mezo-parallel | mezo-fused | adam
    mezo: MezoConfig = MezoConfig()
    adam: AdamConfig = AdamConfig()
    n_steps: int = 100
    seed: int = 0
    ckpt_dir: Optional[str] = None
    snapshot_every: int = 100
    log_every: int = 10
    straggler_redundancy: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainerConfig,
                 batches: Iterator[Any], mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.model = build_model(model_cfg)
        self.batches = batches
        self.mesh = mesh
        self.log = log_fn
        self.losses: list = []
        self._straggler = (StragglerPolicy(
            train_cfg.mezo.n_directions,
            train_cfg.straggler_redundancy)
            if train_cfg.straggler_redundancy else None)

        self.ckpt = (CheckpointManager(
            train_cfg.ckpt_dir,
            mezo_cfg=(train_cfg.mezo if train_cfg.optimizer != "adam"
                      else None),
            snapshot_every=train_cfg.snapshot_every)
            if train_cfg.ckpt_dir else None)

    # -- setup ------------------------------------------------------------
    def init_params(self) -> PyTree:
        return self.model.init(jax.random.PRNGKey(self.tcfg.seed))

    def _mezo_cfg(self) -> MezoConfig:
        c = self.tcfg.mezo
        if self._straggler:
            c = dataclasses.replace(
                c, n_directions=self._straggler.total)
        return c

    # -- main loop --------------------------------------------------------
    def train(self, params: Optional[PyTree] = None,
              fail_at: Optional[int] = None) -> PyTree:
        """Runs to n_steps with auto-resume. ``fail_at`` raises at that
        step (fault-injection for tests)."""
        start = 0
        if params is None:
            params = self.init_params()
            if self.ckpt:
                restored, start = self.ckpt.restore(params)
                if restored is not None:
                    params = restored
                    self.log(f"[trainer] resumed at step {start}")

        opt_state = None
        if self.tcfg.optimizer == "adam":
            opt_state = adam_init(params)

        mcfg = self._mezo_cfg()
        step_fn = {"mezo": mezo_step, "mezo-parallel": mezo_step_vmapdir,
                   "mezo-fused": mezo_step_fused,
                   "adam": None}[self.tcfg.optimizer]

        t0 = time.perf_counter()
        for step in range(start, self.tcfg.n_steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(self.batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            seed = zrng.fold_seed(jnp.uint32(self.tcfg.seed), step)

            if self.tcfg.optimizer == "adam":
                params, opt_state, loss = grad_train_step(
                    self.model.loss, params, batch, opt_state,
                    self.tcfg.adam)
                aux = None
                self.losses.append(float(loss))
            else:
                mask = None
                if self._straggler:
                    mask = jnp.asarray(self._straggler.mask())
                params, aux = step_fn(self.model.loss, params, batch, seed,
                                      mcfg, mask)
                self.losses.append(float(aux.loss))

            if self.ckpt:
                self.ckpt.on_step(step, params, aux)
            if step % self.tcfg.log_every == 0:
                dt = time.perf_counter() - t0
                self.log(f"[trainer] step={step} loss={self.losses[-1]:.4f} "
                         f"({dt:.1f}s)")
        return params
