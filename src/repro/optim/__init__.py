"""Derivative-based baselines (the paper's comparison arm: Adam, SGD)."""

from repro.optim.adam import (AdamConfig, AdamState, adam_init, adam_update,
                              grad_train_step, sgd_train_step)

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "grad_train_step", "sgd_train_step"]
