"""Mamba (selective SSM) layer -- the recurrent sublayer of jamba.

Forward-only selective scan via ``lax.scan`` over time (ZO fine-tuning
never backprops through the scan, so no remat policy is needed -- see
DESIGN.md Sec 5). Decode carries (conv_state, ssm_state) explicitly.

The full-sequence apply threads an optional ``PerturbCtx``: every weight
use applies ``coeff*z`` in place (dense projections via ``ctx``-aware
``L.dense``, conv/SSM leaves via transient ``ctx.perturb``), which is
what lets the hybrid family run the fused ZO loss with zero transient
parameter copies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.models import layers as L
from repro.optim.quant import deq as _deq


def _leaf(p, name, ctx):
    """p[name] + coeff*z under a ctx; the bare (dequantized) leaf
    without one."""
    return _deq(p[name]) if ctx is None else ctx.perturb(name, p[name])


def _dims(cfg, d_model=None):
    d = d_model or cfg.d_model
    d_inner = cfg.mamba_expand * d
    dt_rank = max(1, d // 16)
    return d, d_inner, dt_rank


def mamba_init(cfg, key, d_model=None):
    d, di, dtr = _dims(cfg, d_model)
    n = cfg.mamba_d_state
    ks = jax.random.split(key, 6)
    dt = L._dt(cfg)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": L.dense_init(ks[2], di, dtr + 2 * n, dt),
        "dt_proj": L.dense_init(ks[3], dtr, di, dt, bias=True),
        # A initialized to -[1..n] per channel (S4D-real init)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[4], di, d, dt,
                                 scale=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }


def _ssm_inputs(cfg, p, xc, d_model=None, ctx=None):
    """xc: (B, S, di) post-conv. Returns dt, Bmat, Cmat (f32)."""
    _, _, dtr = _dims(cfg, d_model)
    n = cfg.mamba_d_state
    proj = L.dense(p["x_proj"], xc, _sub(ctx, "x_proj")).astype(jnp.float32)
    dt_raw, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dtp = _sub(ctx, "dt_proj")
    dt = jax.nn.softplus(dt_raw @ _leaf(p["dt_proj"], "w",
                                        dtp).astype(jnp.float32)
                         + _leaf(p["dt_proj"], "b", dtp).astype(jnp.float32))
    return dt, bmat, cmat


def _scan_ssm(p, xc, dt, bmat, cmat, h0=None, ctx=None):
    """Selective scan. xc: (B,S,di); dt: (B,S,di); b/c: (B,S,n)."""
    a = -jnp.exp(_leaf(p, "A_log", ctx))           # (di, n)
    bsz, _, di = xc.shape
    n = a.shape[-1]
    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                  # (B,di),(B,di),(B,n),(B,n)
        da = jnp.exp(dt_t[..., None] * a)          # (B, di, n)
        dbx = (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xc.astype(jnp.float32) * _leaf(p, "D", ctx)
    return y.astype(xc.dtype), h


def _causal_conv(p, x, d_conv, ctx=None):
    """Depthwise causal conv over time. x: (B, S, di)."""
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    conv_w = _leaf(p, "conv_w", ctx)
    out = sum(pad[:, i:i + x.shape[1], :] * conv_w[i]
              for i in range(d_conv))
    return out + _leaf(p, "conv_b", ctx)


def mamba_apply(cfg, p, x, d_model=None, ctx=None):
    """Full-sequence forward. x: (B, S, D) -> (B, S, D)."""
    xz = L.dense(p["in_proj"], x, _sub(ctx, "in_proj"))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, xi, cfg.mamba_d_conv, ctx))
    dt, bmat, cmat = _ssm_inputs(cfg, p, xc, d_model, ctx)
    y, _ = _scan_ssm(p, xc, dt, bmat, cmat, ctx=ctx)
    return L.dense(p["out_proj"], y * jax.nn.silu(z), _sub(ctx, "out_proj"))


def mamba_init_state(cfg, bsz, d_model, dtype):
    di = cfg.mamba_expand * d_model
    return {
        "conv": jnp.zeros((bsz, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((bsz, di, cfg.mamba_d_state), jnp.float32),
    }


def mamba_prefill(cfg, p, state, x, d_model=None):
    """Multi-token continuation: full-sequence mamba from an explicit
    (conv, ssm) state, returning the state after the last token.
    ``mamba_step`` is the S=1 special case; with a zero state this equals
    ``mamba_apply`` (whose implicit conv padding is exactly the zero
    conv window)."""
    d_conv = cfg.mamba_d_conv
    s = x.shape[1]
    xz = L.dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, S, di)
    window = jnp.concatenate([state["conv"], xi], axis=1)
    conv_w = _leaf(p, "conv_w", None)
    xc = sum(window[:, i:i + s, :] * conv_w[i]
             for i in range(d_conv)) + _leaf(p, "conv_b", None)
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _ssm_inputs(cfg, p, xc, d_model)
    y, h = _scan_ssm(p, xc, dt, bmat, cmat, h0=state["ssm"])
    out = L.dense(p["out_proj"], y * jax.nn.silu(z))
    return out, {"conv": window[:, s:, :], "ssm": h}


def mamba_step(cfg, p, state, x, d_model=None):
    """Single decode step. x: (B, 1, D) -> (B, 1, D), updated state."""
    xz = L.dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, 1, di)
    window = jnp.concatenate([state["conv"], xi], axis=1)
    conv_w = _leaf(p, "conv_w", None)
    xc = sum(window[:, i:i + 1, :] * conv_w[i]
             for i in range(cfg.mamba_d_conv)) + _leaf(p, "conv_b", None)
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _ssm_inputs(cfg, p, xc, d_model)
    y, h = _scan_ssm(p, xc, dt, bmat, cmat, h0=state["ssm"])
    out = L.dense(p["out_proj"], y * jax.nn.silu(z))
    return out, {"conv": window[:, 1:, :], "ssm": h}
