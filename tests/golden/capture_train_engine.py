"""Capture TrainEngine goldens (tests/golden/train_engine.json).

One fixed multi-tenant scenario per pinned family: U users through a
batched TrainEngine (fused estimator, sgd rule, K=2 directions), f32 and
int8-base arms. Pins per-user losses and gs projections so any drift in
the user-batched step -- vmap lane arithmetic, masked merge, seed
derivation, store replay -- names the family and user it broke.

Run from the repo root to (re)capture:

  PYTHONPATH=src python tests/golden/capture_train_engine.py
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import rng as zrng
from repro.core.mezo import MezoConfig
from repro.models import build_model
from repro.optim.quant import quantize_tree
from repro.serve.adapters import AdapterStore
from repro.train import TrainEngine, TrainJob

ARCHS = {"gemma-2b": "dense", "rwkv6-7b": "ssm"}
U, T, B, S = 4, 3, 2, 8
MZ = MezoConfig(eps=1e-3, lr=1e-4, n_directions=2)
ENGINE_SEED = 7


def make_batches(cfg, user: str, n_steps: int):
    """Deterministic per-(user, step) LM batches (numpy: platform-stable)."""
    salt = zrng.leaf_salt(user)
    out = []
    for step in range(n_steps):
        rng = np.random.default_rng((salt, step))
        toks = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
        out.append({"tokens": toks[:, :-1], "targets": toks[:, 1:],
                    "loss_mask": np.ones((B, S), np.float32)})
    return out


def run_engine(arch: str, quant: str):
    """The pinned scenario: U jobs, one engine, full run."""
    cfg = get_config(arch).reduced()
    base = build_model(cfg).init(jax.random.PRNGKey(0))
    if quant == "int8":
        base = quantize_tree(base, with_delta=True)
    store = AdapterStore(jax.tree.map(
        lambda x: x, base), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=U, seed=ENGINE_SEED)
    for i in range(U):
        u = f"u{i}"
        eng.submit(TrainJob(user=u, batches=make_batches(cfg, u, T),
                            n_steps=T))
    return eng.run(), store


def capture(arch: str) -> dict:
    rec = {"family": ARCHS[arch], "arms": {}}
    arms = ("f32", "int8") if ARCHS[arch] == "dense" else ("f32",)
    for arm in arms:
        results, _ = run_engine(arch, "int8" if arm == "int8" else "none")
        rec["arms"][arm] = {
            "losses": {r.user: r.losses for r in results},
            "gs": {r.user: [row["gs"] for row in r.records]
                   for r in results},
        }
    return rec


def main():
    out = {arch: capture(arch) for arch in ARCHS}
    path = os.path.join(os.path.dirname(__file__), "train_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
