"""FFN blocks: dense MLP and top-k MoE.

Both are stateless -- the runtime calls ``apply`` in every mode. The MoE
block is the one place the fused ZO path still takes a *scoped* transient
copy (``ctx.materialize`` of the expert sub-dict): expert weights are
3/4-D stacked leaves consumed inside sort-based dispatch, so there is no
2-D use site to fuse into. That copy is per-block, per-layer-slice --
never the whole model."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MoE
from repro.models.blocks.base import BlockType, register_block
from repro.optim.quant import dequantize_tree


def _mlp_apply(cfg, p, x, rc, ctx=None):
    return L.mlp_apply(cfg, p, x, ctx), jnp.float32(0.0)


def _moe_apply(cfg, p, x, rc, ctx=None):
    """Expert weights are 3/4-D stacked leaves consumed inside
    sort-based dispatch, so both the fused ZO path and the quantized
    base take a scoped transient copy here: ``ctx.materialize``
    (perturb + dequant) with a ctx, a plain dequant without one --
    per-block, per-layer-slice, never the whole model."""
    fn = MoE.moe_apply_ep if cfg.moe_ep else MoE.moe_apply
    moe_p = dequantize_tree(p) if ctx is None else ctx.materialize(p)
    return fn(cfg, moe_p, x)


MLP = register_block(BlockType(name="mlp", init=L.mlp_init,
                               apply=_mlp_apply))
MOE = register_block(BlockType(name="moe", init=MoE.moe_init,
                               apply=_moe_apply))
