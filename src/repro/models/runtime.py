"""Generic backbone engine: one forward/loss/cache/decode/prefill for
every architecture family.

A family is described *declaratively* by a :class:`ModelPlan` -- an
(optional) encoder :class:`StackPlan` plus the main stack, each a tuple
of :class:`Sublayer` rows naming a norm leaf, a mixer param path, and a
registered block type (``repro.models.blocks``). The engine then owns
the one residual pattern every family shares::

    for each layer (lax.scan over stacked (L, ...) leaves):
        for each sublayer:  x = x + block(norm(x))

and derives all five model functions from it:

* ``forward`` -- full-sequence, threads a :class:`PerturbCtx` into every
  block (``ctx.scope(stack)/.at_layer(l)/.scope(mixer path)``), so the
  fused ZO perturbed forward works identically for dense, MoE, hybrid,
  rwkv6, and enc-dec -- no family ever materializes a transient
  perturbed parameter copy;
* ``loss`` -- the ZO objective (CE + aux for LMs, CLS head for
  encoder classification);
* ``init_cache`` -- the unified StateCache: a nested dict mirroring the
  param tree (``{scope: {mixer path: {leaf: (L, B, ...)}}}``); every
  leaf has layers on axis 0 and batch on axis 1, for every family
  (serving scatters/merges slots with one tree.map, no per-family axis
  table);
* ``decode_step`` / ``prefill`` -- the scan walks (layer params, layer
  state) together; blocks marked ``mutable_state=False`` (cross-attn
  K/V) are read from the original buffers and never copied through the
  scan.

Family assembly (which sublayers exist, how init keys route) lives in
``repro.models.transformer``; this module is family-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.perturb_ctx import sub as _sub
from repro.models import layers as L
from repro.models.blocks import RunCtx, get_block
from repro.models.config import ModelConfig

PyTree = Any
AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# plans


@dataclasses.dataclass(frozen=True)
class Sublayer:
    """One residual unit: ``x = x + block(norm(x))``.

    ``ln`` / ``mixer`` are '/'-separated param paths *within* the layer
    dict (hybrid nests them under ``sub_i``); ``block`` names a
    registered :class:`~repro.models.blocks.BlockType`; ``opts`` are
    static kwargs forwarded to the block (e.g. ``("causal", False)`` for
    encoder self-attention).
    """
    ln: str
    mixer: str
    block: str
    opts: Tuple[Tuple[str, Any], ...] = ()


@dataclasses.dataclass(frozen=True)
class StackPlan:
    """A scanned stack of identical layers under ``params[scope]``."""
    scope: str
    n_layers: int
    sublayers: Tuple[Sublayer, ...]


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    cfg: ModelConfig
    stack: StackPlan                     # the decoded / backbone stack
    encoder: Optional[StackPlan] = None  # enc-dec only (stateless)


# ---------------------------------------------------------------------------
# nested-path helpers ('/'-separated paths inside a layer dict)


def _get(d, path: str):
    for part in path.split("/"):
        d = d[part]
    return d


def _set(d, path: str, val):
    parts = path.split("/")
    for part in parts[:-1]:
        d = d.setdefault(part, {})
    d[parts[-1]] = val


def _copy_tree(d):
    return {k: _copy_tree(v) if isinstance(v, dict) else v
            for k, v in d.items()}


def _scoped(ctx, path: str):
    """ctx.scope() down a '/'-separated path (None passes through)."""
    if ctx is None:
        return None
    for part in path.split("/"):
        ctx = ctx.scope(part)
    return ctx


def _decode_positions(pos):
    """Learned-pos embedding indices for a scalar or per-slot pos."""
    pos = jnp.asarray(pos)
    return pos[:, None] if pos.ndim else jnp.full((1,), pos)


# ---------------------------------------------------------------------------
# the one residual loop, in three modes


def _stack_apply(cfg, stack: StackPlan, params, x, rc: RunCtx, ctx):
    """Full-sequence stack: scan over stacked layer params. The perturb
    ctx binds the scan index (``at_layer``) so per-layer z slices match
    each stacked leaf's field."""
    blocks_p = params[stack.scope]
    sctx = None if ctx is None else ctx.scope(stack.scope)

    def body(carry, xs):
        bp, li = xs
        h, aux = carry
        bctx = None if sctx is None else sctx.at_layer(li)
        for sl in stack.sublayers:
            bt = get_block(sl.block)
            z = L.norm_apply(cfg, _get(bp, sl.ln), h, _scoped(bctx, sl.ln))
            y, a = bt.apply(cfg, _get(bp, sl.mixer), z, rc,
                            ctx=_scoped(bctx, sl.mixer), **dict(sl.opts))
            h = h + y
            aux = aux + a
        return (h, aux), None

    n_layers = jax.tree_util.tree_leaves(blocks_p)[0].shape[0]
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (blocks_p, jnp.arange(n_layers, dtype=jnp.uint32)))
    return x, aux


def _stack_seq(cfg, stack: StackPlan, params, state, x, rc: RunCtx,
               mode: str):
    """Stateful stack walk (mode 'decode' or 'prefill'): the scan
    consumes (layer params, layer state) and emits updated state for
    every mutable-state block."""
    blocks_p = params[stack.scope]

    def body(h, xs):
        bp, ls = xs
        new = {}
        for sl in stack.sublayers:
            bt = get_block(sl.block)
            z = L.norm_apply(cfg, _get(bp, sl.ln), h)
            opts = dict(sl.opts)
            if bt.stateful:
                fn = bt.decode_step if mode == "decode" else bt.prefill
                y, ns = fn(cfg, _get(bp, sl.mixer), _get(ls, sl.mixer),
                           z, rc, **opts)
                if bt.mutable_state:
                    _set(new, sl.mixer, ns)
            else:
                y, _ = bt.apply(cfg, _get(bp, sl.mixer), z, rc, **opts)
            h = h + y
        return h, new

    x, stacked = jax.lax.scan(body, x, (blocks_p, state))
    out = _copy_tree(state)           # read-only leaves keep their buffers
    for sl in stack.sublayers:
        bt = get_block(sl.block)
        if bt.stateful and bt.mutable_state:
            _set(out, sl.mixer, _get(stacked, sl.mixer))
    return x, out


def _window_scan(bt, cfg, bp, ls, z, rc, opts):
    """Generic verify fallback for recurrent blocks (mamba, rwkv):
    run ``decode_step`` once per window offset and stack every mutable
    state leaf along a leading (W,) axis -- offset i's entry is the
    state after consuming window tokens 0..i, so the serving engine can
    commit exactly the accepted prefix and discard the rest (the
    recurrent analogue of the page-table rollback)."""
    zw = jnp.moveaxis(z, 1, 0)[:, :, None, :]       # (W, B, 1, D)

    def step(carry, zi):
        y, ns = bt.decode_step(cfg, bp, carry, zi, rc, **opts)
        return ns, (y, ns)

    _, (ys, states) = jax.lax.scan(step, ls, zw)
    y = jnp.moveaxis(ys[:, :, 0, :], 0, 1)          # (B, W, D)
    return y, states                                # leaves: (W, B, ...)


def _stack_verify(cfg, stack: StackPlan, params, state, x, rc: RunCtx):
    """Stateful stack walk over a speculative-verify window: paged
    blocks score the whole window in one call (``BlockType.verify``);
    recurrent blocks fall back to a per-offset decode_step scan whose
    mutable state gains a leading (W,) axis (see :func:`_window_scan`);
    read-only state (cross-attn K/V) passes through untouched."""
    blocks_p = params[stack.scope]

    def body(h, xs):
        bp, ls = xs
        new = {}
        for sl in stack.sublayers:
            bt = get_block(sl.block)
            z = L.norm_apply(cfg, _get(bp, sl.ln), h)
            opts = dict(sl.opts)
            if not bt.stateful:
                y, _ = bt.apply(cfg, _get(bp, sl.mixer), z, rc, **opts)
            elif bt.verify is not None:
                y, ns = bt.verify(cfg, _get(bp, sl.mixer),
                                  _get(ls, sl.mixer), z, rc, **opts)
                if bt.mutable_state:
                    _set(new, sl.mixer, ns)
            elif not bt.mutable_state:      # read-only: window in one call
                y, _ = bt.decode_step(cfg, _get(bp, sl.mixer),
                                      _get(ls, sl.mixer), z, rc, **opts)
            else:
                y, ns = _window_scan(bt, cfg, _get(bp, sl.mixer),
                                     _get(ls, sl.mixer), z, rc, opts)
                _set(new, sl.mixer, ns)
            h = h + y
        return h, new

    x, stacked = jax.lax.scan(body, x, (blocks_p, state))
    out = _copy_tree(state)           # read-only leaves keep their buffers
    for sl in stack.sublayers:
        bt = get_block(sl.block)
        if bt.stateful and bt.mutable_state:
            _set(out, sl.mixer, _get(stacked, sl.mixer))
    return x, out


def _stack_chunk(cfg, stack: StackPlan, params, state, x, rc: RunCtx):
    """Stateful stack walk over one prompt chunk written straight into
    the page pool: paged blocks take the whole chunk in one call
    (``BlockType.prefill_paged`` -- K/V scattered through ``rc.pages``,
    read via the flash-prefill sweep); recurrent blocks (mamba, rwkv)
    advance their dense state through their ordinary multi-token
    ``prefill`` scan -- final state only, no per-offset snapshots, which
    is what separates this from :func:`_stack_verify` (prefill never
    rolls back); read-only state (cross-attn K/V) passes through."""
    blocks_p = params[stack.scope]

    def body(h, xs):
        bp, ls = xs
        new = {}
        for sl in stack.sublayers:
            bt = get_block(sl.block)
            z = L.norm_apply(cfg, _get(bp, sl.ln), h)
            opts = dict(sl.opts)
            if not bt.stateful:
                y, _ = bt.apply(cfg, _get(bp, sl.mixer), z, rc, **opts)
            elif bt.prefill_paged is not None:
                y, ns = bt.prefill_paged(cfg, _get(bp, sl.mixer),
                                         _get(ls, sl.mixer), z, rc, **opts)
                if bt.mutable_state:
                    _set(new, sl.mixer, ns)
            elif not bt.mutable_state:      # read-only: chunk in one call
                y, _ = bt.decode_step(cfg, _get(bp, sl.mixer),
                                      _get(ls, sl.mixer), z, rc, **opts)
            else:
                y, ns = bt.prefill(cfg, _get(bp, sl.mixer),
                                   _get(ls, sl.mixer), z, rc, **opts)
                _set(new, sl.mixer, ns)
            h = h + y
        return h, new

    x, stacked = jax.lax.scan(body, x, (blocks_p, state))
    out = _copy_tree(state)           # read-only leaves keep their buffers
    for sl in stack.sublayers:
        bt = get_block(sl.block)
        if bt.stateful and bt.mutable_state:
            _set(out, sl.mixer, _get(stacked, sl.mixer))
    return x, out


# ---------------------------------------------------------------------------
# model functions (what build_model wires into the Model facade)


def forward(plan: ModelPlan, params, batch, last_only=False, perturb=None):
    """Train / prefill forward -> (logits, aux). ``perturb`` switches on
    the fused perturbed forward uniformly across families."""
    cfg = plan.cfg
    x = L.embed_apply(cfg, params["embed"], batch["tokens"],
                      ctx=_sub(perturb, "embed"))
    n_prefix = 0
    if "patch_embeds" in batch:                    # vlm: prepend stub patches
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        n_prefix = batch["patch_embeds"].shape[1]
    enc_out = None
    if plan.encoder is not None:
        e = batch["enc_embeds"].astype(L._dt(cfg))
        erc = RunCtx(positions=jnp.arange(e.shape[1])[None])
        e, _ = _stack_apply(cfg, plan.encoder, params, e, erc, perturb)
        enc_out = L.norm_apply(cfg, params["ln_enc"], e,
                               _sub(perturb, "ln_enc"))
    rc = RunCtx(positions=jnp.arange(x.shape[1])[None],
                kv_mask=batch.get("attn_mask"), enc_out=enc_out)
    x, aux = _stack_apply(cfg, plan.stack, params, x, rc, perturb)
    x = L.norm_apply(cfg, params["ln_f"], x, _sub(perturb, "ln_f"))
    if cfg.n_classes:                  # CLS pooling + head (roberta/SST-2);
        cls = x[:, 0].astype(jnp.float32)          # last_only has no meaning
        return L.dense(params["cls_head"], jnp.tanh(cls),
                       _sub(perturb, "cls_head")), aux
    if n_prefix:
        x = x[:, n_prefix:]
    if last_only:          # prefill: only the next-token logits are needed
        x = x[:, -1:]
    return L.unembed(cfg, params["embed"], params.get("lm_head"), x,
                     ctx=perturb), aux


def softmax_xent(logits, targets, mask=None):
    """Cross entropy that never materializes an f32 copy of the logits.

    Two measured pathologies avoided (EXPERIMENTS.md Sec Perf):
      * ``take_along_axis`` on vocab-sharded logits all-gathers the full
        logits across the model axis -- replaced by a one-hot masked sum
        (local + tiny psum);
      * upcasting logits to f32 with multiple consumers (lse AND gold)
        writes a full f32 logits tensor to HBM (12.9 GB/chip/pass on
        granite train_4k) -- instead, max/gold read the bf16 logits and
        the f32 exp-sum is a single-consumer fusion into its reduce.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    sumexp = jnp.sum(
        jnp.exp((logits - m[..., None]).astype(jnp.float32)), axis=-1)
    lse = m.astype(jnp.float32) + jnp.log(sumexp)
    gold = jnp.sum(
        jnp.where(jnp.arange(logits.shape[-1]) == targets[..., None],
                  logits, jnp.zeros((), logits.dtype)),
        axis=-1).astype(jnp.float32)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-9)
    return jnp.mean(nll)


def loss(plan: ModelPlan, params, batch, perturb=None):
    """The ZO objective. ``perturb`` (a PerturbCtx) switches on the fused
    perturbed forward: params stay untouched, every weight use applies
    coeff*z in place (see core/perturb_ctx.py) -- in every family."""
    logits, aux = forward(plan, params, batch, perturb=perturb)
    if plan.cfg.n_classes:                            # roberta/SST-2 path
        return softmax_xent(logits, batch["label"])
    ce = softmax_xent(logits, batch["targets"], batch.get("loss_mask"))
    return ce + AUX_LOSS_WEIGHT * aux


def init_cache(plan: ModelPlan, bsz, max_len, dtype):
    """The unified StateCache: every leaf is (n_layers, B, ...) -- layer
    stack on axis 0, batch on axis 1, regardless of family."""
    cfg = plan.cfg
    sub: dict = {}
    for sl in plan.stack.sublayers:
        bt = get_block(sl.block)
        if not bt.stateful:
            continue
        spec = bt.state_spec(cfg, bsz, max_len, dtype)
        _set(sub, sl.mixer,
             {name: jnp.zeros((plan.stack.n_layers,) + shape, dt)
              for name, (shape, dt) in spec.items()})
    return {plan.stack.scope: sub}


def plan_pages(plan: ModelPlan) -> bool:
    """True iff any sublayer of the main stack has pageable state."""
    return any(get_block(sl.block).paged_state_spec is not None
               for sl in plan.stack.sublayers)


def init_paged_cache(plan: ModelPlan, bsz, n_pages, page_size, dtype,
                     max_len=None):
    """Paged StateCache: pageable leaves (attention K/V) become
    ``(n_layers, n_pages, page_size, ...)`` pool leaves shared by every
    slot through a page table; everything else (mamba/rwkv recurrent
    state -- O(1) per slot) keeps the dense (n_layers, B, ...) layout.
    Physical page 0 is reserved as the trash page (unallocated table
    entries and masked-out writes land there), so allocators hand out
    pages 1..n_pages-1.
    """
    cfg = plan.cfg
    sub: dict = {}
    for sl in plan.stack.sublayers:
        bt = get_block(sl.block)
        if not bt.stateful:
            continue
        if bt.paged_state_spec is not None:
            spec = bt.paged_state_spec(cfg, dtype)
            leaves = {name: jnp.zeros(
                (plan.stack.n_layers, n_pages, page_size) + shape, dt)
                for name, (shape, dt) in spec.items()}
        else:
            spec = bt.state_spec(cfg, bsz, max_len or cfg.max_seq, dtype)
            leaves = {name: jnp.zeros((plan.stack.n_layers,) + shape, dt)
                      for name, (shape, dt) in spec.items()}
        _set(sub, sl.mixer, leaves)
    return {plan.stack.scope: sub}


def decode_step(plan: ModelPlan, params, cache, tokens, pos, pages=None,
                write_mask=None):
    """tokens: (B, 1) -> logits (B, 1, V); cache updated at ``pos``
    (scalar, or (B,) for continuous batching). With a paged cache,
    ``pages`` is the (B, n_live) physical page table slice and
    ``write_mask`` optionally confines state writes to a slot subset
    (masked slots scatter into the trash page)."""
    cfg = plan.cfg
    x = L.embed_apply(cfg, params["embed"], tokens,
                      positions=_decode_positions(pos))
    rc = RunCtx(pos=pos, pages=pages, write_mask=write_mask)
    x, state = _stack_seq(cfg, plan.stack, params, cache[plan.stack.scope],
                          x, rc, "decode")
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {plan.stack.scope: state}


def verify_window(plan: ModelPlan, params, cache, tokens, pos, pages=None,
                  write_mask=None):
    """Speculative-verify scoring call: tokens (B, W) at per-slot
    positions ``pos .. pos + W - 1`` -> logits (B, W, V). Paged K/V for
    the whole window is written through the page table (so the pool
    afterwards holds the *verifier's* K/V at every window position);
    recurrent state leaves come back with a leading (W,) axis -- one
    snapshot per window offset -- for the engine's accept-prefix commit.
    ``write_mask`` is (B, W): offsets past a slot's live window scatter
    into the trash page."""
    cfg = plan.cfg
    pos = jnp.asarray(pos)
    positions = pos[:, None] + jnp.arange(tokens.shape[1])[None, :]
    x = L.embed_apply(cfg, params["embed"], tokens, positions=positions)
    rc = RunCtx(pos=pos, pages=pages, write_mask=write_mask)
    x, state = _stack_verify(cfg, plan.stack, params,
                             cache[plan.stack.scope], x, rc)
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {plan.stack.scope: state}


def prefill_chunk(plan: ModelPlan, params, cache, tokens, pos, pages=None,
                  write_mask=None):
    """Chunked prefill into a paged cache: tokens (B, C) at per-slot
    positions ``pos .. pos + C - 1`` -> (logits (B, C, V), cache). Paged
    K/V for the chunk is written through the page table (the admission
    reservation guarantees ``pages`` covers ``pos + C - 1``); recurrent
    state leaves advance in place through each block's prefill scan --
    no dense B=1 prompt cache, no install scatter. ``write_mask`` is
    (B,) or (B, C): masked slots/offsets scatter into the trash page."""
    cfg = plan.cfg
    pos = jnp.asarray(pos)
    positions = pos[:, None] + jnp.arange(tokens.shape[1])[None, :]
    x = L.embed_apply(cfg, params["embed"], tokens, positions=positions)
    rc = RunCtx(pos=pos, positions=positions, pages=pages,
                write_mask=write_mask)
    x, state = _stack_chunk(cfg, plan.stack, params,
                            cache[plan.stack.scope], x, rc)
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {plan.stack.scope: state}


def prefill(plan: ModelPlan, params, cache, tokens):
    """Fused prefill: one jitted call over the whole (B, P) prompt writes
    cache positions [0, P) and returns next-token logits (B, 1, V) --
    P decode_step dispatches collapsed into one layer-scan."""
    cfg = plan.cfg
    x = L.embed_apply(cfg, params["embed"], tokens)
    rc = RunCtx(positions=jnp.arange(tokens.shape[1])[None])
    x, state = _stack_seq(cfg, plan.stack, params, cache[plan.stack.scope],
                          x, rc, "prefill")
    x = L.norm_apply(cfg, params["ln_f"], x[:, -1:])
    logits = L.unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, {plan.stack.scope: state}
