"""Host-side data pipeline: background prefetch + sharded device_put.

At multi-host scale each process feeds only its addressable shard of the
global batch; ``jax.make_array_from_process_local_data`` handles the
host->device scatter. On single-process meshes ``jax.device_put`` with a
NamedSharding does the same thing.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np


class DataPipeline:
    def __init__(self, source: Iterator[Any], sharding=None,
                 prefetch: int = 2):
        self._source = source
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch,
            self._sharding)

    def _worker(self):
        try:
            for batch in self._source:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
        except Exception as e:  # surface errors on the consumer side
            self._q.put(e)
        self._q.put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, StopIteration):
            raise item
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
