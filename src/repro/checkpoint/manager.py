"""Checkpoint manager: snapshots + replay log + auto-resume.

Policy: full param snapshot every ``snapshot_every`` steps (expensive,
rare), replay-log append every step (cheap, always). ``restore()`` finds
the newest snapshot, replays the log tail, and reports the step to resume
from -- giving per-step restart granularity at snapshot-level IO cost.
For the Adam baseline (no replay log possible) it degrades to
snapshot-only recovery, losing the steps since the last snapshot: this
asymmetry is measured in benchmarks/table1_memory.py.
"""

from __future__ import annotations

import os
from typing import Any, Optional, Tuple

from repro.checkpoint import store
from repro.checkpoint.replay_log import ReplayLog, replay_into

PyTree = Any


class CheckpointManager:
    def __init__(self, ckpt_dir: str, mezo_cfg=None,
                 snapshot_every: int = 100, keep: int = 2):
        self.dir = ckpt_dir
        self.cfg = mezo_cfg
        self.snapshot_every = snapshot_every
        self.keep = keep
        self.log = (ReplayLog(os.path.join(ckpt_dir, "replay.jsonl"))
                    if mezo_cfg is not None else None)

    # ---- save -----------------------------------------------------------
    def on_step(self, step: int, params: PyTree, aux=None):
        if self.log is not None and aux is not None:
            self.log.append(step, aux.seed, aux.gs, self.cfg.lr,
                            self.cfg.eps)
        if step % self.snapshot_every == 0:
            store.save_params(self.dir, step, params)
            self._gc()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))

    # ---- restore --------------------------------------------------------
    def restore(self, like: PyTree, shardings=None
                ) -> Tuple[Optional[PyTree], int]:
        """Returns (params, next_step) or (None, 0) when nothing saved."""
        snap = store.latest_step(self.dir)
        if snap is None:
            return None, 0
        params = store.load_params(self.dir, snap, like, shardings)
        if self.log is None:
            return params, snap + 1
        records = ReplayLog.read(os.path.join(self.dir, "replay.jsonl"),
                                 after_step=snap)
        params, last = replay_into(params, records, self.cfg)
        return params, max(snap, last) + 1
