"""Straggler mitigation via redundant direction evaluation.

In gradient-based DP training a straggler holds up the whole all-reduce
(its gradient *shard* is irreplaceable). ZO direction-parallelism changes
the failure algebra: every pod's contribution is an i.i.d. SPSA sample,
so dropping a late pod just shrinks the direction sample -- the estimator
stays unbiased. The scheme:

  * schedule K + R directions per step (R redundant),
  * accept the first K to finish (here: a deadline against the median of
    an EMA of per-direction latencies),
  * renormalize the update over survivors (core.engine._direction_coeffs).

On a synchronous single-controller run we cannot observe true per-pod
latencies, so the policy also accepts externally reported "slow pod"
sets (the launcher would wire these from pod heartbeats); tests drive it
deterministically.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    n_directions: int            # K: directions used by the update
    redundancy: int = 0          # R: extra directions scheduled
    deadline_factor: float = 3.0  # drop observations slower than f x median
    ema: float = 0.9

    def __post_init__(self):
        self._lat = np.zeros(self.total, np.float64)
        self._seen = False

    @property
    def total(self) -> int:
        return self.n_directions + self.redundancy

    @property
    def seen(self) -> bool:
        """True once at least one latency vector has been observed."""
        return self._seen

    @property
    def ema_latencies(self) -> np.ndarray:
        """Copy of the (total,) EMA latency estimates (zeros before the
        first observation). Feeding an entry's own EMA back through
        :meth:`observe` leaves it unchanged, so a caller tracking items
        that report latencies one at a time (the fleet coordinator's
        workers) can update a single entry per observation."""
        return self._lat.copy()

    def observe(self, latencies: Sequence[float]):
        lat = np.asarray(latencies, np.float64)
        if lat.shape != (self.total,):
            raise ValueError(
                f"StragglerPolicy.observe: latencies shape {lat.shape} "
                f"!= expected ({self.total},) (n_directions="
                f"{self.n_directions} + redundancy={self.redundancy})")
        self._lat = lat if not self._seen else (
            self.ema * self._lat + (1 - self.ema) * lat)
        self._seen = True

    def deadline(self) -> float:
        """Per-item latency budget: ``deadline_factor`` x the median of
        the EMA latencies -- the same cutoff :meth:`mask` drops slow
        observations with, exposed as an absolute duration so an async
        coordinator can expire (and re-issue) a direction lease instead
        of merely masking it. ``inf`` until the first observation: with
        no latency model yet, nothing can be declared late."""
        if not self._seen:
            return float("inf")
        return float(self.deadline_factor
                     * max(np.median(self._lat), 1e-9))

    def mask(self, slow: Optional[Sequence[int]] = None) -> np.ndarray:
        """(K+R,) 0/1 mask of accepted directions.

        Keeps the fastest ``n_directions`` among those not marked slow;
        if everything is marked slow, falls back to keeping all (progress
        beats purity).
        """
        m = np.ones(self.total, np.float32)
        if slow is not None:
            m[np.asarray(list(slow), int)] = 0.0
        if self._seen:
            med = np.median(self._lat[m > 0]) if (m > 0).any() else 0.0
            m[self._lat > self.deadline_factor * max(med, 1e-9)] = 0.0
        if m.sum() == 0:
            return np.ones(self.total, np.float32)
        # keep at most n_directions fastest survivors
        if m.sum() > self.n_directions and self._seen:
            order = np.argsort(np.where(m > 0, self._lat, np.inf))
            keep = order[: self.n_directions]
            m2 = np.zeros_like(m)
            m2[keep] = 1.0
            m = m2
        return m
