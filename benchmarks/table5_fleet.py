"""Table 5 (async fleet training): elastic direction service at worker
counts {1, 4, 16} with 20% injected stragglers.

PocketLLM trains on one phone; the async direction service trains ONE
job across a fleet of them. Because a ZO step is commutative scalar
accumulation of (seed, gs), the coordinator can apply results at
whatever pace the fleet delivers them -- staleness-decayed instead of
discarded -- so modeled throughput scales with worker count even when a
fifth of the fleet runs 5x slow (expired leases are re-issued; late
results are dropped, never logged).

Three claims this table pins:

  * scaling: modeled (virtual-time) steps/s grows with fleet size
    despite the stragglers -- the discrete-event sim is deterministic,
    so these numbers are machine-independent and gate-able;
  * learning: eval loss on a fixed held-out batch still descends under
    asynchrony (staleness-decayed updates remain useful signal);
  * replayability: every arm's staleness-bearing log reconstructs the
    live final params bit-exactly (atol=0) from theta_0 alone.

Reduced-config CPU run; wall-clock is not measured (the modeled fleet
makespan is the headline, and it is exact).
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.replay_log import ReplayLog, replay_into
from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batch_at, synthetic_lm_corpus
from repro.models import build_model
from repro.runtime.fleet import FaultSpec, FleetSim, WorkerSpec

STEPS, BATCH, SEQ = 80, 8, 32
FLEETS = (1, 4, 16)
STRAGGLER_FRACTION, STRAGGLER_SCALE = 0.2, 5.0


def _fleet(n: int):
    n_slow = round(STRAGGLER_FRACTION * n)
    return [WorkerSpec("flagship",
                       FaultSpec(jitter=0.2,
                                 latency_scale=STRAGGLER_SCALE
                                 if i >= n - n_slow else 1.0))
            for i in range(n)], n_slow


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32)))),
        a, b)))


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config("opt-1.3b").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab=128)
    mz = MezoConfig(eps=1e-2, lr=1e-2, n_directions=8,
                    staleness_decay=0.95)
    stream = synthetic_lm_corpus(BATCH * (SEQ + 1) * 64, cfg.vocab, seed=1)

    def batches(step: int):
        return lm_batch_at(step, BATCH, SEQ, cfg.vocab, stream, seed=1)

    # held-out eval batch: a step index the training run never reaches
    eval_batch = {k: jnp.asarray(v) for k, v in
                  lm_batch_at(10**6, BATCH, SEQ, cfg.vocab, stream,
                              seed=1).items()}
    model = build_model(cfg)
    eval_loss = jax.jit(model.loss)
    table = {"steps": STEPS, "batch": BATCH, "seq": SEQ,
             "straggler_fraction": STRAGGLER_FRACTION,
             "straggler_scale": STRAGGLER_SCALE,
             "staleness_decay": mz.staleness_decay, "arms": {}}
    rows = []

    with tempfile.TemporaryDirectory() as tmp:
        for n in FLEETS:
            workers, n_slow = _fleet(n)
            log = os.path.join(tmp, f"fleet{n}.jsonl")
            sim = FleetSim(cfg, workers, total_steps=STEPS, mezo_cfg=mz,
                           batches=batches, batch=BATCH, seq=SEQ, seed=0,
                           log_path=log)
            init_loss = float(eval_loss(sim.base_params, eval_batch))
            rep = sim.run()
            final_loss = float(eval_loss(rep.params, eval_batch))
            replayed, _ = replay_into(
                sim.model.init(jax.random.PRNGKey(0)),
                ReplayLog.read(log), mz)
            arm = {"workers": n, "stragglers": n_slow,
                   "virtual_s": rep.virtual_s,
                   "virtual_steps_per_s": rep.virtual_steps_per_s,
                   "reissued": rep.reissued, "dropped": rep.dropped,
                   "max_staleness": int(max(rep.staleness)),
                   "eval_loss_init": init_loss,
                   "eval_loss_final": final_loss,
                   "losses": rep.losses,
                   "replay_bitexact": _max_diff(replayed,
                                                rep.params) == 0.0}
            table["arms"][f"w{n}"] = arm
            rows.append((
                f"fleet/w{n}", 1e6 / arm["virtual_steps_per_s"],
                f"eval {init_loss:.4f}->{final_loss:.4f} "
                f"stale<={arm['max_staleness']} replay="
                f"{'bit-exact' if arm['replay_bitexact'] else 'MISMATCH'}"))
            print(f"[table5] w={n:2d} ({n_slow} stragglers): "
                  f"{arm['virtual_steps_per_s']:.0f} modeled steps/s, "
                  f"eval {init_loss:.4f} -> {final_loss:.4f}, "
                  f"max staleness {arm['max_staleness']}, "
                  f"replay {'bit-exact' if arm['replay_bitexact'] else 'MISMATCH'}")

    path = os.path.join(out_dir, "table5_fleet.json")
    with open(path, "w") as f:
        json.dump(table, f, indent=1)
    print(f"[table5] wrote {path}")
    return rows


if __name__ == "__main__":
    run()
