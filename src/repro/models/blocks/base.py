"""The Block protocol and registry -- one contract for every mixer/FFN.

A *block* is the unit the generic backbone engine
(:mod:`repro.models.runtime`) composes: attention, MLP, MoE, mamba,
rwkv time-mix/channel-mix, cross-attention. Each block implements the
same five-slot protocol over plain param dicts:

  init(cfg, key)                     -> params          (leaf layout)
  apply(cfg, p, x, rc, ctx)          -> (y, aux)        (full sequence)
  state_spec(cfg, bsz, max_len, dt)  -> {name: (shape, dtype)}
  prefill(cfg, p, state, x, rc)      -> (y, new_state)  (multi-token)
  decode_step(cfg, p, state, x, rc)  -> (y, new_state)  (one token)

Conventions:

* the runtime owns the residual pattern -- ``apply`` receives the
  *normed* input and returns only the branch output ``y`` (plus an aux
  scalar, 0 for everything but MoE load balancing);
* ``ctx`` is an optional :class:`~repro.core.perturb_ctx.PerturbCtx`
  already scoped to this block's param sub-dict -- threading it through
  ``apply`` is what gives every family the fused ZO perturbed forward;
* ``rc`` (:class:`RunCtx`) carries the per-call tensors a block may
  need: positions, the decode position, a KV validity mask, the encoder
  output for cross-attention;
* ``state_spec`` declares per-layer decode state as ``{name: (shape,
  dtype)}`` *without* the layer axis -- the runtime stacks each leaf to
  ``(n_layers, B, ...)``, so every StateCache leaf in every family has
  the batch on axis 1 (the invariant `serve/engine.py` relies on);
* ``mutable_state=False`` marks state that decode reads but never
  writes (cross-attention K/V): the runtime keeps the original buffers
  instead of copying them through the layer scan every token.

Stateless blocks (MLP, MoE) leave the state slots ``None``; the runtime
calls ``apply`` in every mode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call inputs shared by every block of a stack (all optional)."""
    positions: Any = None      # (B, S) int positions (full / prefill)
    pos: Any = None            # scalar or (B,) decode position
    kv_mask: Any = None        # (B, T) key-validity mask (full mode)
    enc_out: Any = None        # (B, T_enc, D) encoder output (cross-attn)
    pages: Any = None          # (B, n_live) physical page ids (paged decode)
    write_mask: Any = None     # (B,) bool: slots allowed to write state;
    #                            verify mode: (B, W) per-window-offset


@dataclasses.dataclass(frozen=True)
class BlockType:
    name: str
    init: Callable                       # (cfg, key) -> params
    apply: Callable                      # (cfg, p, x, rc, ctx=, **opts)
    state_spec: Optional[Callable] = None
    prefill: Optional[Callable] = None   # (cfg, p, state, x, rc, **opts)
    decode_step: Optional[Callable] = None
    mutable_state: bool = True
    # per-token decode state that can live in a shared page pool:
    # (cfg, dtype) -> {name: (per-position shape, dtype)}; the runtime
    # builds (n_layers, n_pages, page_size, *shape) pool leaves and the
    # block's decode_step reads/writes them through rc.pages. None means
    # the block's state stays (n_layers, B, ...) even in a paged cache
    # (mamba/rwkv recurrent state is O(1) per slot -- nothing to page).
    paged_state_spec: Optional[Callable] = None
    # speculative-verify window: (cfg, p, state, x(B, W, D), rc, **opts)
    # -> (y, new_state), scoring W candidate tokens at positions
    # rc.pos..rc.pos+W-1 in one call (causal within the window). Blocks
    # without it fall back to the runtime's per-offset decode_step scan,
    # which additionally stacks a (W, ...) axis onto mutable state so
    # the engine can roll back to the last accepted offset.
    verify: Optional[Callable] = None
    # chunked prefill straight into the page pool: (cfg, p, state,
    # x(B, C, D), rc, **opts) -> (y, new_state). A C-token prompt chunk
    # at positions rc.pos..rc.pos+C-1 writes its own K/V through
    # rc.pages (masked slots/offsets -> trash page) and attends to all
    # prior cached positions plus causally within the chunk -- no dense
    # B=1 prompt cache ever exists. Blocks without it (recurrent state)
    # advance dense state through their ordinary ``prefill`` scan.
    prefill_paged: Optional[Callable] = None

    @property
    def stateful(self) -> bool:
        return self.state_spec is not None


_BLOCKS: Dict[str, BlockType] = {}


def register_block(bt: BlockType) -> BlockType:
    _BLOCKS[bt.name] = bt
    return bt


def get_block(name: str) -> BlockType:
    if name not in _BLOCKS:
        raise ValueError(f"unknown block type {name!r}; "
                         f"registered: {block_names()}")
    return _BLOCKS[name]


def block_names():
    return sorted(_BLOCKS)
