"""Paper Sec 4.1: fine-tune RoBERTa-large on SST-2 with MeZO.

Reduced RoBERTa config + synthetic SST-2 (planted sentiment lexicon);
reports loss and accuracy before/after. This is the paper's Figure-1
experiment end-to-end, including the replay-log checkpoint flow.

  PYTHONPATH=src python examples/finetune_sst2.py
"""

import sys, os, shutil
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import sst2_batches, synthetic_sst2
from repro.models import build_model
from repro.runtime import Trainer, TrainerConfig


def accuracy(model, params, toks, labels):
    logits, _ = model.forward(params, {"tokens": jnp.asarray(toks)})
    pred = np.asarray(jnp.argmax(logits, -1))
    return float((pred == labels).mean())


def main():
    cfg = get_config("roberta-large").reduced(n_layers=2, d_model=128,
                                              d_ff=256, vocab=256)
    model = build_model(cfg)
    seq, steps = 32, 300

    ckpt = "/tmp/pocketllm_sst2_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    tc = TrainerConfig(optimizer="mezo",
                       mezo=MezoConfig(eps=1e-2, lr=2e-2, n_directions=8),
                       n_steps=steps, ckpt_dir=ckpt, snapshot_every=100,
                       log_every=50)
    tr = Trainer(cfg, tc, sst2_batches(16, seq, cfg.vocab, seed=5))

    p0 = tr.init_params()
    toks, labels = synthetic_sst2(256, seq, cfg.vocab, seed=99)
    acc0 = accuracy(model, p0, toks, labels)
    params = tr.train(jax.tree.map(jnp.copy, p0))
    acc1 = accuracy(model, params, toks, labels)

    print(f"\nSST-2 (synthetic): acc {acc0:.3f} -> {acc1:.3f}; "
          f"loss {tr.losses[0]:.3f} -> {tr.losses[-1]:.3f}")
    print(f"replay log: {os.path.getsize(os.path.join(ckpt, 'replay.jsonl'))}"
          f" bytes for {steps} steps (vs {sum(l.size*l.dtype.itemsize for l in jax.tree.leaves(p0))/1e6:.1f} MB params)")
    assert acc1 > acc0, "fine-tuning should help"


if __name__ == "__main__":
    main()
