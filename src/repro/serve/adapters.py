"""ZO adapters: a user's entire fine-tune as a few-KB replay log.

A MeZO trajectory is fully determined by ``(theta_base, [(seed_t, gs_t,
lr_t, eps_t)])`` -- so a *personalized* model is not a parameter tree but
a scalar log replayable onto shared base weights with zero forward
passes (``checkpoint/replay_log.py``). That makes the replay log a
derivative-free analogue of the side-tuning adapters of MobiLLM
(arXiv 2502.20421) and the additive deltas of PAE MobiLLM
(arXiv 2507.01216): per-user state is ~KB, and one device can hold
thousands of users' fine-tunes next to a single copy of the base model.

:class:`AdapterStore` is the serving-side registry:

* ``put`` / ``import_checkpoint`` / ``save`` / ``load`` -- adapters move
  as replay-log JSONL (the exact CheckpointManager on-disk format);
* ``materialize(user)`` -- ``base + replay`` on demand, LRU-cached with
  a byte budget so hot users pay zero replays and cold users evict;
* ``export_delta`` / ``put_delta`` -- a compact int8 additive-delta form
  (via ``optim/compression.py``) for adapters whose logs grew long
  enough that replay latency matters more than bit-exactness.

Materializing from records is bit-identical to
``CheckpointManager.restore`` for the pristine-base-point estimators
(vmapdir / fused); the int8 delta form is lossy by one quantization
roundtrip per leaf.

The shared base may itself be an int8 *quantized* base
(``optim.quant.quantize_tree``): replay then writes each quantized
leaf's f32 delta while the int8 values stay frozen and shared, so a
device serves thousands of users over a ~1 byte/param base -- the
memory story of the paper's Table 1, composed with personalization.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.replay_log import ReplayLog
from repro.core.engine import SGD, UpdateRule
from repro.core.mezo import MezoConfig
from repro.optim.quant import (int8_dequantize, int8_quantize, is_quantized,
                               tree_is_quantized, with_delta)

PyTree = Any

#: adapter id meaning "no adapter" -- materializes the shared base tree.
BASE_USER = "__base__"


@dataclasses.dataclass(frozen=True)
class ZOAdapter:
    """One user's fine-tune: step-ordered replay-log records."""
    user: str
    records: Tuple[dict, ...]

    @property
    def n_steps(self) -> int:
        return len(self.records)

    @property
    def nbytes(self) -> int:
        """Wire size of the adapter itself (the scalars, not the tree)."""
        return len(json.dumps(list(self.records)).encode())


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


class AdapterStore:
    """Per-user ZO adapters over one shared base tree.

    ``mezo_cfg`` must carry the ``dist`` / ``weight_decay`` the users
    trained with (lr / eps travel inside each record; K is the logged
    ``gs`` length) -- a mismatched ``dist`` silently materializes a
    different model, exactly like replaying a log with the wrong RNG.
    Runs trained with a stateful update rule (momentum) must pass the
    same ``update_rule`` (and matching ``n_directions`` /
    ``momentum_window`` in ``mezo_cfg``): the whole log replays through
    ``rule.update_fn`` from a fresh state, reproducing the live
    trajectory exactly as ``CheckpointManager._replay_state`` does.
    """

    def __init__(self, base_params: PyTree, mezo_cfg: Optional[MezoConfig]
                 = None, cache_bytes: Optional[int] = None,
                 update_rule: Optional[UpdateRule] = None):
        self.base = base_params
        self.cfg = mezo_cfg or MezoConfig()
        self.cache_bytes = cache_bytes
        self.rule = update_rule or SGD
        self._adapters: Dict[str, ZOAdapter] = {}
        self._deltas: Dict[str, list] = {}
        self._cache: "OrderedDict[str, PyTree]" = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "materialize_s": 0.0, "last_materialize_s": 0.0}

    # ---- registration ----------------------------------------------------
    def put(self, user: str, records: List[dict]) -> ZOAdapter:
        if user == BASE_USER:
            raise ValueError(f"{BASE_USER!r} is reserved for the base tree")
        ad = ZOAdapter(user=user, records=tuple(records))
        self._adapters[user] = ad
        self._cache.pop(user, None)      # re-registered => stale cache entry
        return ad

    def import_checkpoint(self, user: str, ckpt_dir: str) -> ZOAdapter:
        """Adopt a CheckpointManager run's replay log as this user's
        adapter (the whole log: base_params must be the run's theta_0)."""
        path = os.path.join(ckpt_dir, "replay.jsonl")
        records = ReplayLog.read(path)
        if not records:
            raise FileNotFoundError(f"no replay records under {ckpt_dir}")
        return self.put(user, records)

    def save(self, user: str, path: str) -> int:
        """Write the adapter as replay-log JSONL; returns bytes written."""
        ad = self._adapters[user]
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            for rec in ad.records:
                f.write(json.dumps(rec) + "\n")
        return os.path.getsize(path)

    def load(self, user: str, path: str) -> ZOAdapter:
        records = ReplayLog.read(path)
        if not records:
            # an empty adapter would silently serve the base model
            raise FileNotFoundError(f"no replay records in {path}")
        return self.put(user, records)

    def users(self) -> List[str]:
        return sorted(set(self._adapters) | set(self._deltas))

    def records(self, user: Optional[str]) -> Tuple[dict, ...]:
        """The user's stored replay records, step-ordered (empty for the
        base id and for users never ``put`` -- a fresh user resumes from
        nothing). This is the TrainEngine's resume source."""
        if user is None or user == BASE_USER:
            return ()
        ad = self._adapters.get(user)
        return ad.records if ad is not None else ()

    # ---- materialization -------------------------------------------------
    def materialize(self, user: Optional[str]) -> PyTree:
        """``base + replay(user)`` (or base + int8 delta), LRU-cached."""
        if user is None or user == BASE_USER:
            return self.base
        if user in self._cache:
            self.stats["hits"] += 1
            self._cache.move_to_end(user)
            return self._cache[user]
        t0 = time.perf_counter()
        if user in self._adapters:
            params = self._replay(self._adapters[user].records)
        elif user in self._deltas:
            params = self._apply_delta(self._deltas[user])
        else:
            raise KeyError(f"unknown adapter {user!r}; have {self.users()}")
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        self.stats["misses"] += 1
        self.stats["materialize_s"] += dt
        self.stats["last_materialize_s"] = dt
        self._cache[user] = params
        self._evict()
        return params

    def _replay_records(self, records) -> Tuple[PyTree, PyTree]:
        """Replay a log through the update rule from a fresh state --
        identical arithmetic to the live steps (sgd: the classic
        seed-replay sweep; momentum: the history window rolls forward
        from empty exactly as training rolled it). Returns the full
        ``(params, opt)`` pair so a trainer can resume mid-log with the
        rule's state intact, not just serve the weights.

        A quantized base (optim/quant.py) works unchanged: the replay
        writes each quantized leaf's f32 delta while the int8 values
        stay frozen and shared across every user -- the resident cost of
        N personalized models is one int8 base plus N delta sets. A
        frozen (delta-less) base gains zero deltas here first."""
        params, opt = self.base, self.rule.init_fn(self.cfg)
        if tree_is_quantized(params):
            params = with_delta(params)
        for rec in records:
            c = dataclasses.replace(self.cfg, lr=rec["lr"], eps=rec["eps"])
            mask = rec.get("mask")
            params, opt = self.rule.update_fn(
                params, opt, np.uint32(rec["seed"]),
                np.asarray(rec["gs"], np.float32),
                None if mask is None else np.asarray(mask, np.float32), c)
        return params, opt

    def _replay(self, records) -> PyTree:
        return self._replay_records(records)[0]

    def materialize_state(self, user: Optional[str]
                          ) -> Tuple[PyTree, PyTree, int]:
        """Resume point for a fine-tune job: ``(params, opt,
        n_replayed)`` after replaying the user's stored records from the
        base. ``None`` / ``BASE_USER`` / a never-seen user start fresh
        (zero records); a user known only by a compact int8 delta raises
        -- deltas are lossy, so resuming training from one would fork
        the trajectory from its own replay log."""
        if (user is not None and user != BASE_USER
                and user in self._deltas and user not in self._adapters):
            raise ValueError(
                f"adapter {user!r} exists only as a lossy int8 delta; "
                f"training resume needs the exact replay log")
        recs = self.records(user)
        params, opt = self._replay_records(recs)
        return params, opt, len(recs)

    def cached_bytes(self) -> int:
        """Bytes the cache actually adds on top of the shared base.

        Quantized leaves in a materialized tree alias the base's int8
        values and scales by reference (replay only writes the f32
        delta), so counting them per cached user would evict hot users
        over phantom bytes -- only the per-user delta is charged."""
        total = 0
        for t in self._cache.values():
            for leaf in jax.tree_util.tree_leaves(t, is_leaf=is_quantized):
                if is_quantized(leaf):
                    total += (leaf.delta.nbytes
                              if leaf.delta is not None else 0)
                else:
                    total += tree_bytes(leaf)
        return total

    def _evict(self):
        """Drop least-recently-used materialized trees past the byte
        budget -- always keeping the most recent one so the caller's
        working tree is never evicted under it."""
        if self.cache_bytes is None:
            return
        while len(self._cache) > 1 and self.cached_bytes() > self.cache_bytes:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1

    # ---- compact int8 delta form ----------------------------------------
    @staticmethod
    def _eff(leaf):
        """Effective f32 value of a (possibly quantized) leaf."""
        return (leaf.dequantize_f32() if is_quantized(leaf)
                else jnp.asarray(leaf, jnp.float32))

    def export_delta(self, user: str) -> list:
        """Compact the adapter into per-leaf int8 ``(q, scale)`` deltas
        against base -- O(params) bytes/8 instead of O(steps) replay work.
        Lossy (one int8 roundtrip); leaf order is ``jax.tree.leaves``
        (quantized leaves atomic: the delta is over effective weights)."""
        mat = self.materialize(user)
        out = []
        for b, m in zip(
                jax.tree.leaves(self.base, is_leaf=is_quantized),
                jax.tree.leaves(mat, is_leaf=is_quantized)):
            d = self._eff(m) - self._eff(b)
            q, s = int8_quantize(d)
            out.append((np.asarray(q), float(np.asarray(s))))
        return out

    def put_delta(self, user: str, delta: list):
        if user == BASE_USER:
            raise ValueError(f"{BASE_USER!r} is reserved for the base tree")
        self._deltas[user] = delta
        self._cache.pop(user, None)

    def _apply_delta(self, delta: list) -> PyTree:
        leaves, treedef = jax.tree_util.tree_flatten(
            self.base, is_leaf=is_quantized)
        if len(delta) != len(leaves):
            raise ValueError(f"delta has {len(delta)} leaves, base has "
                             f"{len(leaves)}")
        new = []
        for b, (q, s) in zip(leaves, delta):
            d = int8_dequantize(jnp.asarray(q), s)
            if is_quantized(b):
                # keep the int8 base resident; the delta stays additive
                prev = b.delta if b.delta is not None else 0.0
                new.append(dataclasses.replace(b, delta=prev + d))
            else:
                new.append((jnp.asarray(b, jnp.float32) + d).astype(b.dtype))
        return jax.tree_util.tree_unflatten(treedef, new)

    def save_delta(self, user: str, path: str) -> int:
        if not path.endswith(".npz"):      # np.savez appends it silently
            path += ".npz"
        arrays = {}
        for i, (q, s) in enumerate(self._deltas.get(user)
                                   or self.export_delta(user)):
            arrays[f"q_{i}"] = q
            arrays[f"s_{i}"] = np.float32(s)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.savez(path, **arrays)
        return os.path.getsize(path)

    def load_delta(self, user: str, path: str):
        if not path.endswith(".npz"):
            path += ".npz"
        data = np.load(path)
        n = len([k for k in data.files if k.startswith("q_")])
        self.put_delta(user, [(data[f"q_{i}"], float(data[f"s_{i}"]))
                              for i in range(n)])
