"""Composable ZO engine: direction estimators × update rules.

PocketLLM's memory claim rests on one invariant: a training step is fully
described by the scalar pair ``(seed, gs)``. That makes the step function
a *product* of two orthogonal choices —

* a **DirectionEvaluator** realizes ``L(theta ± eps*z_k)`` for K
  directions and returns the projected gradients ``gs``:

  - ``walk``    — sequential in-place walk (perturb / eval /
    counter-perturb / eval / restore), the paper-faithful memory profile;
  - ``vmapdir`` — directions evaluated concurrently under ``vmap``
    (one transient perturbed copy per direction, pod-shardable);
  - ``fused``   — the perturbation never touches the parameters: a
    :class:`~repro.core.perturb_ctx.PerturbCtx` with ``coeff=±eps`` rides
    into the forward and dense projections compute ``X @ (W + coeff*z)``
    via the Pallas ``zo_matmul`` kernel (0 param sweeps/direction);

* an **UpdateRule** turns ``(seed, gs)`` into a parameter update:

  - ``sgd``      — the shared f32 seed-replay tail
    ``theta -= lr * sum_k coeffs_k * gs_k * z_k``;
  - ``momentum`` — ZO momentum via *truncated seed replay*: classical
    momentum needs a param-sized velocity buffer (exactly the memory MeZO
    exists to avoid), but the ZO velocity is structurally
    ``v_t = sum_i beta^{t-i} g_i z_i``, so a window of M
    ``(seed, gs, coeffs)`` rows represents it in O(M*K) scalars and the
    update replays the window with geometric weights.

Every estimator×update combination shares the same f32 update arithmetic
(:func:`_direction_coeffs` / :func:`_apply_direction_updates`), which is
what keeps the ``(seed, gs)`` replay log interchangeable across
strategies — bit-exact for the pristine-base-point estimators
(``vmapdir``, ``fused``), and up to walk roundoff drift for ``walk``.

The engine also owns:

* :class:`TrainState` — the one pytree a step consumes and produces
  (params, step counter, update-rule state). The checkpoint manager
  snapshots/restores it whole, so momentum history and Adam moments
  survive a crash (``checkpoint/manager.py``).
* a name-based **strategy registry** (builder pattern): the trainer and
  CLI resolve ``--estimator fused --update momentum`` (or a legacy alias
  like ``"mezo-fused"``) through :func:`build_strategy` /
  :func:`get_strategy` instead of a hand-written dict.
* :meth:`ZOStrategy.run_chunk` — a multi-step ``lax.scan`` over a stacked
  batch pytree that amortizes per-step dispatch overhead
  (``benchmarks/table2_walltime.py``'s chunked arm).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as zrng
from repro.core.perturb import add_scaled_z
from repro.core.perturb_ctx import PerturbCtx

PyTree = Any
# (params, batch) -> scalar; the fused estimator additionally requires a
# ``perturb=`` keyword (models built by repro.models.build_model accept it)
LossFn = Callable[..., jnp.ndarray]


# ---------------------------------------------------------------------------
# configs / aux / state


@dataclasses.dataclass(frozen=True)
class MezoConfig:
    eps: float = 1e-3
    lr: float = 1e-6
    n_directions: int = 1          # K: SPSA directions averaged per step
    dist: str = "rademacher"       # or "gaussian" (MeZO-repo default)
    use_kernel: bool = False       # route 2-D leaves via Pallas zo_add
    momentum: float = 0.0          # ZO momentum via truncated seed replay
    momentum_window: int = 8       # directions of history to replay
    weight_decay: float = 0.0
    staleness_decay: float = 0.8   # async fleet: update scale decay^stale


@dataclasses.dataclass
class MezoAux:
    loss: jnp.ndarray         # mean of (l+ + l-)/2 over directions
    gs: jnp.ndarray           # (K,) projected gradients -- the replay log
    seed: jnp.ndarray         # uint32 step seed -- the replay log
    grad_norm_est: jnp.ndarray


jax.tree_util.register_pytree_node(
    MezoAux,
    lambda a: ((a.loss, a.gs, a.seed, a.grad_norm_est), None),
    lambda _, c: MezoAux(*c),
)


@dataclasses.dataclass(frozen=True)
class TrainState:
    """Everything a training step consumes and produces.

    ``opt`` is the update rule's state: ``{}`` for sgd, the truncated
    seed-replay window for momentum, or an ``optim.adam.AdamState`` for
    the gradient baseline. Snapshotting this pytree whole (instead of bare
    params) is what makes momentum history / Adam moments survive resume.

    ``params`` may be a quantized base (``optim.quant.quantize_tree``
    with deltas attached): the int8 values + scales stay frozen, every
    update rule writes the f32 ``delta`` of each quantized leaf through
    the same ``add_scaled_z`` replay arithmetic, and the replay log is
    byte-identical to an f32 run's -- checkpoints and adapters need no
    format change.
    """
    params: PyTree
    step: jnp.ndarray              # uint32 scalar: completed-step count
    opt: PyTree


jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda s: (((jax.tree_util.DictKey("params"), s.params),
                (jax.tree_util.DictKey("step"), s.step),
                (jax.tree_util.DictKey("opt"), s.opt)), None),
    lambda _, c: TrainState(*c),
)


# ---------------------------------------------------------------------------
# the shared f32 update tail (identical across every strategy — this is
# what keeps the (seed, gs) replay log interchangeable)


def _direction_coeffs(kk: int, lr, direction_mask):
    """Per-direction update coefficients: ``-lr/K``, or with a straggler
    mask ``-lr * m_k / max(sum(m), 1)`` — an unbiased mean over survivors.

    The unmasked branch multiplies by the f32 reciprocal instead of
    dividing: ``lr`` may now arrive traced (the user-batched engine
    threads per-user lr vectors through jit), and XLA rewrites division
    by a *constant* K into multiply-by-reciprocal while the eager replay
    paths (checkpoint manager, adapter store) would keep true division —
    a last-ulp fork for non-power-of-two K. One explicit multiply keeps
    live jit and eager replay on identical ops, hence bit-identical.
    """
    if direction_mask is None:
        return jnp.full((kk,), -lr * jnp.float32(1.0 / kk), jnp.float32)
    m = jnp.asarray(direction_mask, jnp.float32).reshape(kk)
    return -lr * m / jnp.maximum(m.sum(), 1.0)


def _staleness_coeffs(kk: int, lr, direction_mask, staleness, decay):
    """Per-direction coefficients for an *asynchronously delivered*
    direction set: the synchronous coefficients scaled by
    ``decay ** staleness``, where ``staleness`` counts the updates
    applied between the worker's params snapshot and this apply.

    ZO tolerates this where SGD cannot -- a stale ``gs`` is still an
    unbiased directional sample at a nearby point, so down-weighting
    (rather than discarding) keeps slow workers contributing. The decay
    is one extra f32 multiply on top of :func:`_direction_coeffs`
    (``x * 1.0`` is exact for staleness 0, so a fresh result is
    bit-identical to the synchronous path), and both the live fleet
    coordinator and log replay compute it from the same logged integer
    -- which is what keeps async runs bit-replayable.
    """
    base = _direction_coeffs(kk, lr, direction_mask)
    scale = jnp.float32(decay) ** jnp.asarray(staleness, jnp.float32)
    return base * scale


def _apply_direction_updates(params, seed, gs, coeffs, cfg: MezoConfig):
    """theta += sum_k coeffs[k] * gs[k] * z_k, z_k regenerated per k."""
    k_tot = gs.shape[0]

    def body(p, kg):
        k, g, c = kg
        return add_scaled_z(p, zrng.fold_seed(seed, k), c * g,
                            dist=cfg.dist, use_kernel=cfg.use_kernel), None

    params, _ = jax.lax.scan(
        body, params, (jnp.arange(k_tot, dtype=jnp.uint32), gs, coeffs))
    return params


def _decay(params, wd_coeff):
    if wd_coeff is None:
        return params
    from repro.optim.quant import is_quantized

    def leaf(p):
        if is_quantized(p):
            # decay the effective weight (q*scale + delta) by folding it
            # entirely into the f32 delta: (q*s + d)(1-c) = q*s +
            # (d*(1-c) - c*q*s). The int8 values AND the power-of-two
            # scales stay frozen -- mutating the scale would break the
            # exact-product property the atol=0 fused-vs-materialized
            # parity rests on. Delta-less leaves are frozen (same
            # semantics as add_scaled_z) and pass through.
            if p.delta is None:
                return p
            wd = jnp.asarray(wd_coeff, jnp.float32)
            return dataclasses.replace(
                p, delta=p.delta * (1.0 - wd) - wd * p.base_f32())
        return ((p * (1.0 - wd_coeff)).astype(p.dtype)
                if jnp.issubdtype(p.dtype, jnp.floating) else p)

    return jax.tree.map(leaf, params, is_leaf=is_quantized)


# ---------------------------------------------------------------------------
# direction evaluators


@dataclasses.dataclass(frozen=True)
class DirectionEvaluator:
    """How ``theta ± eps*z`` is realized for the 2K loss evaluations.

    eval_fn: (loss_fn, params, batch, seed, cfg, eps=None)
    -> (params, gs, ls). ``params`` is threaded through because the
    in-place walk mutates (and restores) it; pristine evaluators return
    it untouched. ``eps`` optionally overrides ``cfg.eps`` with a traced
    f32 scalar — the jitted steps always pass it so the projected
    gradient ``(l+ - l-) / (2 eps)`` is a true division for constant and
    traced eps alike (XLA rewrites division by a *baked* constant into
    multiply-by-reciprocal, which would fork the last ulp between the
    sequential and user-batched paths).

    pristine: the base point is never written during evaluation, so the
    (seed, gs) replay log reconstructs the step bit-exactly.
    donate: the step jit may donate the input TrainState's buffers.
    """
    name: str
    eval_fn: Callable[..., Tuple[PyTree, jnp.ndarray, jnp.ndarray]]
    pristine: bool
    donate: bool


def _f32(value, default: float):
    """Traced-or-config f32 scalar (``None`` -> the config constant)."""
    return jnp.float32(default) if value is None \
        else jnp.asarray(value, jnp.float32)


def _eval_walk(loss_fn: LossFn, params: PyTree, batch: Any, seed,
               cfg: MezoConfig, eps=None):
    """Sequential in-place walk: peak memory = params + one forward."""
    eps = _f32(eps, cfg.eps)

    def one_dir(p, k):
        s = zrng.fold_seed(seed, k)
        p = add_scaled_z(p, s, eps, dist=cfg.dist, use_kernel=cfg.use_kernel)
        lp = loss_fn(p, batch)
        p = add_scaled_z(p, s, -2.0 * eps, dist=cfg.dist,
                         use_kernel=cfg.use_kernel)
        lm = loss_fn(p, batch)
        # restore to base point for the next direction
        p = add_scaled_z(p, s, eps, dist=cfg.dist, use_kernel=cfg.use_kernel)
        return p, ((lp - lm) / (2.0 * eps), 0.5 * (lp + lm))

    params, (gs, ls) = jax.lax.scan(
        one_dir, params, jnp.arange(cfg.n_directions, dtype=jnp.uint32))
    return params, gs, ls


def _eval_vmapdir(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                  cfg: MezoConfig, eps=None):
    """Direction-parallel evaluation: the K-way vmap axis is what the
    launcher shards over the ``pod`` mesh axis; the only cross-pod
    exchange is the (K,) vector ``gs``."""
    eps = _f32(eps, cfg.eps)

    def eval_dir(k):
        s = zrng.fold_seed(seed, k)
        lp = loss_fn(add_scaled_z(params, s, eps, dist=cfg.dist), batch)
        lm = loss_fn(add_scaled_z(params, s, -eps, dist=cfg.dist), batch)
        return (lp - lm) / (2.0 * eps), 0.5 * (lp + lm)

    gs, ls = jax.vmap(eval_dir)(
        jnp.arange(cfg.n_directions, dtype=jnp.uint32))
    return params, gs, ls


def _eval_fused(loss_fn: LossFn, params: PyTree, batch: Any, seed,
                cfg: MezoConfig, eps=None):
    """Fused perturbed forward: 0 param sweeps per direction. ``loss_fn``
    must accept a ``perturb=`` keyword; both sides of each direction see
    the exact z-fields ``add_scaled_z`` would apply, so losses match
    ``vmapdir`` bit-for-bit on the jnp path in f32."""
    eps = _f32(eps, cfg.eps)

    def one_dir(_, k):
        s = zrng.fold_seed(seed, k)
        ctx = PerturbCtx(seed=s, coeff=eps, dist=cfg.dist,
                         use_kernel=cfg.use_kernel)
        lp = loss_fn(params, batch, perturb=ctx)
        lm = loss_fn(params, batch,
                     perturb=dataclasses.replace(ctx, coeff=-eps))
        return None, ((lp - lm) / (2.0 * eps), 0.5 * (lp + lm))

    _, (gs, ls) = jax.lax.scan(one_dir, None,
                               jnp.arange(cfg.n_directions, dtype=jnp.uint32))
    return params, gs, ls


# ---------------------------------------------------------------------------
# update rules


@dataclasses.dataclass(frozen=True)
class UpdateRule:
    """How (seed, gs) becomes a parameter update.

    init_fn:   cfg -> opt state pytree (shapes only depend on cfg).
    update_fn: (params, opt, seed, gs, direction_mask, cfg, lr=None)
               -> (params, opt). Consumes only scalars beyond params —
               this same function is the checkpoint manager's replay
               primitive (zero forward passes on recovery). ``lr``
               optionally overrides ``cfg.lr`` with a traced f32 scalar
               (the user-batched engine threads per-user lr vectors).
    """
    name: str
    init_fn: Callable[[MezoConfig], PyTree]
    update_fn: Callable[..., Tuple[PyTree, PyTree]]


def _sgd_init(cfg: MezoConfig) -> PyTree:
    return {}


def _sgd_update(params, opt, seed, gs, direction_mask, cfg: MezoConfig,
                lr=None):
    seed = jnp.asarray(seed, jnp.uint32)
    gs = jnp.asarray(gs, jnp.float32).reshape(-1)
    lr = _f32(lr, cfg.lr)
    coeffs = _direction_coeffs(gs.shape[0], lr, direction_mask)
    if cfg.weight_decay:
        params = _decay(params, lr * cfg.weight_decay)
    return _apply_direction_updates(params, seed, gs, coeffs, cfg), opt


def _stale_sgd_update(params, opt, seed, gs, direction_mask,
                      cfg: MezoConfig, lr=None, staleness=None):
    """sgd with staleness decay: the async fleet's update rule.

    ``staleness=None``/``0`` degenerates to :func:`_sgd_update`
    bit-exactly (the decay multiply is by exactly 1.0), so the
    checkpoint manager can replay a stale-sgd log tail through the
    standard ``update_fn(params, opt, seed, gs, mask, cfg)`` call and a
    mixed log (sync steps + async steps) stays coherent.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    gs = jnp.asarray(gs, jnp.float32).reshape(-1)
    lr = _f32(lr, cfg.lr)
    coeffs = _staleness_coeffs(gs.shape[0], lr, direction_mask,
                               0 if staleness is None else staleness,
                               cfg.staleness_decay)
    if cfg.weight_decay:
        params = _decay(params, lr * cfg.weight_decay)
    return _apply_direction_updates(params, seed, gs, coeffs, cfg), opt


def momentum_history_init(cfg: MezoConfig) -> PyTree:
    """Empty truncated-replay window: M rows of (seed, gs, coeffs).
    Zero rows are exact no-ops (g=0 ⇒ 0*z added)."""
    m, k = cfg.momentum_window, cfg.n_directions
    return {"seeds": jnp.zeros((m,), jnp.uint32),
            "gs": jnp.zeros((m, k), jnp.float32),
            "coeffs": jnp.zeros((m, k), jnp.float32)}


def _momentum_update(params, opt, seed, gs, direction_mask,
                     cfg: MezoConfig, lr=None):
    """ZO momentum via truncated seed replay (paper Sec 6.2 asks for
    faster derivative-free methods).

    The window stores each step's own f32 coefficients (its lr and
    straggler-mask renormalization), so replaying an entry reproduces
    exactly the sgd update that step would have applied, scaled by the
    geometric weight ``(1-beta) * beta^age``. Memory: M*(2K+1) scalars.
    Compute: M extra z-regeneration sweeps per step (bandwidth-bound,
    no forwards).
    """
    seed = jnp.asarray(seed, jnp.uint32)
    gs = jnp.asarray(gs, jnp.float32).reshape(-1)
    lr = _f32(lr, cfg.lr)
    kk = gs.shape[0]
    beta = jnp.float32(cfg.momentum)
    coeffs = _direction_coeffs(kk, lr, direction_mask)

    # roll the window: newest last
    seeds_h = jnp.concatenate([opt["seeds"][1:], seed[None]])
    gs_h = jnp.concatenate([opt["gs"][1:], gs[None]])
    cf_h = jnp.concatenate([opt["coeffs"][1:], coeffs[None]])

    m = seeds_h.shape[0]
    ages = jnp.arange(m - 1, -1, -1, dtype=jnp.float32)
    weights = ((1.0 - beta) * beta ** ages if cfg.momentum
               else jnp.where(ages == 0, 1.0, 0.0))

    if cfg.weight_decay:
        params = _decay(params, lr * cfg.weight_decay)

    def entry(p, inp):
        s_j, g_j, c_j, w_j = inp

        def dir_body(pp, kgc):
            k, g, c = kgc
            return add_scaled_z(pp, zrng.fold_seed(s_j, k), w_j * c * g,
                                dist=cfg.dist,
                                use_kernel=cfg.use_kernel), None

        p, _ = jax.lax.scan(
            dir_body, p, (jnp.arange(kk, dtype=jnp.uint32), g_j, c_j))
        return p, None

    params, _ = jax.lax.scan(entry, params, (seeds_h, gs_h, cf_h, weights))
    return params, {"seeds": seeds_h, "gs": gs_h, "coeffs": cf_h}


# ---------------------------------------------------------------------------
# the composed strategy


def _step_body(strategy: "ZOStrategy", loss_fn: LossFn, state: TrainState,
               batch: Any, seed, cfg: MezoConfig, direction_mask,
               eps=None, lr=None):
    seed = jnp.asarray(seed, jnp.uint32)
    params, gs, ls = strategy.estimator.eval_fn(
        loss_fn, state.params, batch, seed, cfg, eps=eps)
    params, opt = strategy.update.update_fn(
        params, state.opt, seed, gs, direction_mask, cfg, lr=lr)
    aux = MezoAux(loss=ls.mean(), gs=gs, seed=seed,
                  grad_norm_est=jnp.abs(gs).mean())
    return TrainState(params=params, step=state.step + jnp.uint32(1),
                      opt=opt), aux


# eps/lr ride into every jitted step as *traced* operands (not cfg
# constants baked into the trace): a step's arithmetic is then identical
# whether eps/lr come from the config, a replay record, or a per-user
# vector sliced by vmap — which is what makes the user-batched step
# bit-exact against the sequential one.
@partial(jax.jit, static_argnames=("strategy", "loss_fn", "cfg"))
def _jit_step(strategy, loss_fn, state, batch, seed, cfg,
              direction_mask=None, eps=None, lr=None):
    return _step_body(strategy, loss_fn, state, batch, seed, cfg,
                      direction_mask, eps, lr)


@partial(jax.jit, static_argnames=("strategy", "loss_fn", "cfg"),
         donate_argnums=(2,))
def _jit_step_donate(strategy, loss_fn, state, batch, seed, cfg,
                     direction_mask=None, eps=None, lr=None):
    return _step_body(strategy, loss_fn, state, batch, seed, cfg,
                      direction_mask, eps, lr)


@partial(jax.jit, static_argnames=("strategy", "loss_fn", "cfg"),
         donate_argnums=(2,))
def _jit_chunk(strategy, loss_fn, state, batches, base_seed, cfg,
               eps=None, lr=None):
    base = jnp.asarray(base_seed, jnp.uint32)

    def body(st, batch):
        return _step_body(strategy, loss_fn, st, batch,
                          zrng.fold_seed(base, st.step), cfg, None,
                          eps, lr)

    return jax.lax.scan(body, state, batches)


@partial(jax.jit, static_argnames=("strategy", "loss_fn", "cfg",
                                   "state_axes"),
         donate_argnums=(2,))
def _jit_step_users(strategy, loss_fn, state, batch, seeds, cfg,
                    active, eps, lr, state_axes):
    """One dispatch advances every slot of a user-stacked TrainState.

    ``state`` carries a leading user axis on every per-user leaf (params
    deltas / f32 weights, the step counter, opt state) while quantized
    leaves keep ONE resident int8 base (``q``/``scale`` unbatched —
    ``state_axes`` maps them to ``None``). Each lane runs the exact
    sequential ``_step_body`` with its own (seed, eps, lr), then inactive
    lanes are masked back to their previous state (ragged admission /
    early finishers), so an active lane's trajectory is bit-identical to
    a lone sequential run and an inactive lane is bit-frozen.
    """
    from repro.core.batching import masked_merge

    def lane(st, b, seed, e, l):
        return _step_body(strategy, loss_fn, st, b, seed, cfg, None, e, l)

    axes = state_axes.unflatten()
    new_state, aux = jax.vmap(
        lane, in_axes=(axes, 0, 0, 0, 0), out_axes=(axes, 0))(
        state, batch, seeds, eps, lr)
    return masked_merge(state, new_state, active, axis=0), aux


@dataclasses.dataclass(frozen=True)
class ZOStrategy:
    """One estimator×update pairing, jit-cached per (loss_fn, cfg)."""
    estimator: DirectionEvaluator
    update: UpdateRule

    @property
    def name(self) -> str:
        return f"{self.estimator.name}+{self.update.name}"

    def init_state(self, params: PyTree, cfg: MezoConfig,
                   step: int = 0) -> TrainState:
        return TrainState(params=params, step=jnp.uint32(step),
                          opt=self.update.init_fn(cfg))

    def step(self, loss_fn: LossFn, state: TrainState, batch: Any, seed,
             cfg: MezoConfig, direction_mask=None
             ) -> Tuple[TrainState, MezoAux]:
        fn = _jit_step_donate if self.estimator.donate else _jit_step
        return fn(self, loss_fn, state, batch,
                  jnp.asarray(seed, jnp.uint32), cfg, direction_mask,
                  jnp.float32(cfg.eps), jnp.float32(cfg.lr))

    def lower(self, loss_fn: LossFn, state: TrainState, batch: Any, seed,
              cfg: MezoConfig, direction_mask=None):
        """AOT-lower one step (HLO inspection / cost analysis)."""
        fn = _jit_step_donate if self.estimator.donate else _jit_step
        return fn.lower(self, loss_fn, state, batch,
                        jnp.asarray(seed, jnp.uint32), cfg, direction_mask,
                        jnp.float32(cfg.eps), jnp.float32(cfg.lr))

    def run_chunk(self, loss_fn: LossFn, state: TrainState, batches: Any,
                  base_seed, cfg: MezoConfig
                  ) -> Tuple[TrainState, MezoAux]:
        """Run N steps in one ``lax.scan`` dispatch.

        ``batches`` is a pytree whose leaves are stacked on a leading N
        axis (step i consumes slice i). Per-step seeds are derived inside
        the scan as ``fold_seed(base_seed, state.step)`` — identical to
        the Trainer's per-step derivation, so a chunked run is
        seed-compatible (and replay-log-compatible) with a stepwise one.
        Returns the final state and a stacked MezoAux (leaves gain a
        leading N axis).
        """
        return _jit_chunk(self, loss_fn, state, batches,
                          jnp.asarray(base_seed, jnp.uint32), cfg,
                          jnp.float32(cfg.eps), jnp.float32(cfg.lr))

    def step_users(self, loss_fn: LossFn, state: TrainState, batch: Any,
                   seeds, cfg: MezoConfig, active, eps=None, lr=None
                   ) -> Tuple[TrainState, MezoAux]:
        """Advance U users' slots in ONE dispatch (the multi-tenant step).

        ``state`` is a user-stacked TrainState (``core.batching``): every
        per-user leaf carries a leading U axis, quantized leaves share
        the single resident int8 base. ``batch`` leaves are stacked on a
        leading U axis; ``seeds`` / ``eps`` / ``lr`` are per-user
        vectors; ``active`` is the (U,) slot-occupancy mask — inactive
        lanes come back bit-identical (masked merge), active lanes
        bit-identical to a lone sequential :meth:`step` with the same
        (seed, eps, lr).

        Requires a pristine estimator (``fused`` / ``vmapdir``): the
        walk's in-place sweeps would accumulate roundoff per lane and
        break the replay-log contract the engine's eviction/resume
        machinery rests on.
        """
        if not self.estimator.pristine:
            raise ValueError(
                f"step_users requires a pristine direction estimator "
                f"(got {self.estimator.name!r}): in-place walk roundoff "
                f"would break per-user replay-log bit-parity")
        from repro.core.batching import AxesSpec, user_state_axes
        u = seeds.shape[0]
        eps = jnp.broadcast_to(_f32(eps, cfg.eps), (u,))
        lr = jnp.broadcast_to(_f32(lr, cfg.lr), (u,))
        return _jit_step_users(
            self, loss_fn, state, batch, jnp.asarray(seeds, jnp.uint32),
            cfg, jnp.asarray(active, bool), eps, lr,
            AxesSpec(user_state_axes(state)))


# ---------------------------------------------------------------------------
# the strategy registry (builder pattern: names -> composed strategies)


_ESTIMATORS: Dict[str, DirectionEvaluator] = {}
_UPDATE_RULES: Dict[str, UpdateRule] = {}
_STRATEGY_ALIASES: Dict[str, Tuple[str, str]] = {}
_STRATEGY_CACHE: Dict[Tuple[str, str], ZOStrategy] = {}


def register_estimator(e: DirectionEvaluator) -> DirectionEvaluator:
    _ESTIMATORS[e.name] = e
    return e


def register_update_rule(u: UpdateRule) -> UpdateRule:
    _UPDATE_RULES[u.name] = u
    return u


def register_strategy(name: str, estimator: str, update: str) -> None:
    """Bind a short name (e.g. ``"mezo-fused"``) to a pairing."""
    _STRATEGY_ALIASES[name] = (estimator, update)


def estimator_names():
    return sorted(_ESTIMATORS)


def update_rule_names():
    return sorted(_UPDATE_RULES)


def strategy_names():
    return sorted(_STRATEGY_ALIASES)


def build_strategy(estimator: str = "walk", update: str = "sgd"
                   ) -> ZOStrategy:
    """Compose any estimator×update pairing by name (cached singletons,
    so jit caches keyed on the strategy stay warm)."""
    if estimator not in _ESTIMATORS:
        raise ValueError(
            f"unknown direction estimator {estimator!r}; "
            f"registered: {estimator_names()}")
    if update not in _UPDATE_RULES:
        raise ValueError(
            f"unknown update rule {update!r}; "
            f"registered: {update_rule_names()}")
    key = (estimator, update)
    if key not in _STRATEGY_CACHE:
        _STRATEGY_CACHE[key] = ZOStrategy(
            estimator=_ESTIMATORS[estimator], update=_UPDATE_RULES[update])
    return _STRATEGY_CACHE[key]


def get_strategy(name: str) -> ZOStrategy:
    """Resolve a registered strategy name (legacy ``--optimizer`` values)."""
    if name not in _STRATEGY_ALIASES:
        raise ValueError(
            f"unknown ZO strategy {name!r}; registered strategies: "
            f"{strategy_names()} (any estimator×update pairing is "
            f"constructible via build_strategy: {estimator_names()} × "
            f"{update_rule_names()})")
    return build_strategy(*_STRATEGY_ALIASES[name])


WALK = register_estimator(DirectionEvaluator(
    name="walk", eval_fn=_eval_walk, pristine=False, donate=True))
VMAPDIR = register_estimator(DirectionEvaluator(
    name="vmapdir", eval_fn=_eval_vmapdir, pristine=True, donate=False))
FUSED = register_estimator(DirectionEvaluator(
    name="fused", eval_fn=_eval_fused, pristine=True, donate=True))

SGD = register_update_rule(UpdateRule(
    name="sgd", init_fn=_sgd_init, update_fn=_sgd_update))
STALE_SGD = register_update_rule(UpdateRule(
    name="stale-sgd", init_fn=_sgd_init, update_fn=_stale_sgd_update))
MOMENTUM = register_update_rule(UpdateRule(
    name="momentum", init_fn=momentum_history_init,
    update_fn=_momentum_update))

register_strategy("mezo", "walk", "sgd")
register_strategy("mezo-parallel", "vmapdir", "sgd")
register_strategy("mezo-fused", "fused", "sgd")
register_strategy("mezo-momentum", "vmapdir", "momentum")
register_strategy("mezo-fused-momentum", "fused", "momentum")
