"""Batched multi-tenant TrainEngine: bit-parity, slots, fault recovery.

The contract under test (src/repro/train/engine.py): one vmapped fused
dispatch advancing B resident users is *bit-identical* (atol=0) to B
lone sequential runs -- losses, gs, final deltas, and the replay-log
lines themselves -- and eviction + re-admission through the AdapterStore
resumes exactly where an uninterrupted run would be.

Set REPRO_FAMILY=<family[,family]> to restrict families (the CI
family-matrix job does).
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
import capture_train_engine as ctg  # noqa: E402  (single source of scenario)

from repro.configs import get_config                      # noqa: E402
from repro.core import rng as zrng                        # noqa: E402
from repro.core.engine import build_strategy              # noqa: E402
from repro.models import build_model                      # noqa: E402
from repro.optim.quant import is_quantized, quantize_tree  # noqa: E402
from repro.runtime.trainer import (Trainer, TrainerConfig,  # noqa: E402
                                   train_multi_tenant)
from repro.serve.adapters import AdapterStore             # noqa: E402
from repro.train import (TrainEngine, TrainJob,           # noqa: E402
                         derive_user_seed)

with open(os.path.join(os.path.dirname(__file__), "golden",
                       "train_engine.json")) as f:
    GOLDEN = json.load(f)

_FAM = os.environ.get("REPRO_FAMILY")
ARCHS = [a for a, rec in GOLDEN.items()
         if not _FAM or rec["family"] in _FAM.split(",")]
MZ = ctg.MZ


def _assert_trees_equal(a, b, what=""):
    """Bit-exact tree compare; quantized leaves compare their deltas."""
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(a, is_leaf=is_quantized),
            jax.tree_util.tree_leaves_with_path(b, is_leaf=is_quantized)):
        va = la.delta if is_quantized(la) else la
        vb = lb.delta if is_quantized(lb) else lb
        if va is None and vb is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(va), np.asarray(vb),
            err_msg=f"{what}{jax.tree_util.keystr(pa)}")


def _fresh_base(cfg, quant="none"):
    base = build_model(cfg).init(jax.random.PRNGKey(0))
    return quantize_tree(base, with_delta=True) if quant == "int8" else base


# ---------------------------------------------------------------------------
# acceptance: B=8 batched step vs 8 sequential Trainer runs (atol=0)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if GOLDEN[a]["family"] == "dense"])
def test_b8_engine_bit_equals_8_trainers_int8(arch, tmp_path):
    """The PR's acceptance bar: an 8-user batched TrainEngine on the
    int8 base reproduces 8 sequential Trainer runs bit-for-bit --
    per-step losses, final per-user deltas, and byte-identical
    replay-log files."""
    cfg = get_config(arch).reduced()
    U, T = 8, 3
    users = [f"u{i}" for i in range(U)]
    batches = {u: ctg.make_batches(cfg, u, T) for u in users}

    # -- 8 lone sequential Trainer runs, each logging its replay -------
    trainer_params, trainer_losses = {}, {}
    f32 = _fresh_base(cfg)
    for u in users:
        tcfg = TrainerConfig(
            estimator="fused", update="sgd", quant="int8", mezo=MZ,
            n_steps=T, seed=derive_user_seed(ctg.ENGINE_SEED, u),
            ckpt_dir=str(tmp_path / f"seq-{u}"), snapshot_every=10 ** 6,
            log_every=10 ** 6)
        tr = Trainer(cfg, tcfg, iter(batches[u]), log_fn=lambda s: None)
        trainer_params[u] = tr.train(
            params=jax.tree.map(jnp.copy, f32))
        trainer_losses[u] = list(tr.losses)

    # -- one batched engine, all 8 users resident ----------------------
    store = AdapterStore(_fresh_base(cfg, "int8"), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=U, seed=ctg.ENGINE_SEED,
                      log_dir=str(tmp_path / "engine-logs"))
    for u in users:
        eng.submit(TrainJob(user=u, batches=batches[u], n_steps=T))
    results = {r.user: r for r in eng.run()}

    assert eng.stats.dispatches == T          # 8 users/step, not 8 loops
    for u in users:
        assert results[u].losses == trainer_losses[u], u
        _assert_trees_equal(store.materialize(u), trainer_params[u],
                            what=f"{u}:")
        with open(tmp_path / "engine-logs" / f"{u}.jsonl") as f:
            engine_log = f.read()
        with open(tmp_path / f"seq-{u}" / "replay.jsonl") as f:
            trainer_log = f.read()
        assert engine_log == trainer_log, f"{u}: replay-log lines differ"


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_bit_equals_sequential_strategy(arch):
    """Every pinned family (f32 arm): batched engine vs lone sequential
    strategy runs with the derived per-user seeds, atol=0."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    strat = build_strategy("fused", "sgd")
    base = _fresh_base(cfg)
    results, store = ctg.run_engine(arch, "none")
    for r in results:
        st = strat.init_state(jax.tree.map(jnp.copy, base), MZ)
        us = np.uint32(derive_user_seed(ctg.ENGINE_SEED, r.user))
        bs = ctg.make_batches(cfg, r.user, ctg.T)
        for t in range(ctg.T):
            seed = zrng.fold_seed(jnp.uint32(us), t)
            st, aux = strat.step(model.loss, st, bs[t], seed, MZ)
            assert r.losses[t] == float(np.asarray(aux.loss)), \
                f"{r.user} step {t}"
            np.testing.assert_array_equal(
                np.asarray(r.records[t]["gs"], np.float32),
                np.asarray(aux.gs, np.float32).reshape(-1),
                err_msg=f"{r.user} step {t}")
        _assert_trees_equal(store.materialize(r.user), st.params,
                            what=f"{r.user}:")


# ---------------------------------------------------------------------------
# golden pin


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_losses_and_gs_pinned(arch):
    """The fixed scenario's per-user losses/gs match the pinned capture
    (tests/golden/train_engine.json)."""
    want = GOLDEN[arch]["arms"]
    for arm, pin in want.items():
        results, _ = ctg.run_engine(arch, "int8" if arm == "int8"
                                    else "none")
        got_losses = {r.user: r.losses for r in results}
        for u, losses in pin["losses"].items():
            np.testing.assert_allclose(got_losses[u], losses, rtol=1e-6,
                                       err_msg=f"{arm}/{u}")
        got_gs = {r.user: [rec["gs"] for rec in r.records]
                  for r in results}
        for u, gs in pin["gs"].items():
            np.testing.assert_allclose(got_gs[u], gs, rtol=1e-6,
                                       err_msg=f"{arm}/{u}")


# ---------------------------------------------------------------------------
# slot table: staggered admission, ragged targets, eviction, resume


def _dense_cfg():
    arch = next((a for a in ARCHS if GOLDEN[a]["family"] == "dense"), None)
    if arch is None:
        pytest.skip("dense family filtered out by REPRO_FAMILY")
    return get_config(arch).reduced()


def test_staggered_admission_ragged_targets():
    """More jobs than slots with ragged n_steps: early finishers free
    slots mid-flight, queued jobs admit without draining the batch, and
    every user's trajectory still bit-matches a lone run."""
    cfg = _dense_cfg()
    model = build_model(cfg)
    strat = build_strategy("fused", "sgd")
    base = _fresh_base(cfg)
    targets = {"u0": 2, "u1": 4, "u2": 1, "u3": 3, "u4": 2}
    store = AdapterStore(jax.tree.map(jnp.copy, base), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=2, seed=ctg.ENGINE_SEED)
    for u, n in targets.items():
        eng.submit(TrainJob(user=u, batches=ctg.make_batches(cfg, u, n),
                            n_steps=n))
    results = {r.user: r for r in eng.run()}
    assert eng.stats.finished == len(targets)
    assert eng.stats.user_steps == sum(targets.values())
    for u, n in targets.items():
        st = strat.init_state(jax.tree.map(jnp.copy, base), MZ)
        us = np.uint32(derive_user_seed(ctg.ENGINE_SEED, u))
        bs = ctg.make_batches(cfg, u, n)
        for t in range(n):
            st, aux = strat.step(model.loss, st, bs[t],
                                 zrng.fold_seed(jnp.uint32(us), t), MZ)
        assert results[u].losses[-1] == float(np.asarray(aux.loss)), u
        _assert_trees_equal(store.materialize(u), st.params, what=f"{u}:")


def test_mid_flight_eviction_then_resume_bit_exact():
    """Evict a user mid-run, resubmit: the resumed job starts at the
    flushed step and the final state bit-matches never having been
    evicted (slot was meanwhile reused by another user -- stale-seed
    regression guard)."""
    cfg = _dense_cfg()
    model = build_model(cfg)
    strat = build_strategy("fused", "sgd")
    base = _fresh_base(cfg)
    T = 5
    store = AdapterStore(jax.tree.map(jnp.copy, base), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=1, seed=ctg.ENGINE_SEED)
    eng.submit(TrainJob(user="ua", batches=ctg.make_batches(cfg, "ua", T),
                        n_steps=T))
    eng.step(); eng.step()
    res = eng.evict("ua")
    assert res.evicted and res.n_steps == 2 and len(res.records) == 2
    # another user trains in the freed slot before ua returns
    eng.submit(TrainJob(user="ub", batches=ctg.make_batches(cfg, "ub", 2),
                        n_steps=2))
    eng.submit(TrainJob(user="ua", batches=ctg.make_batches(cfg, "ua", T),
                        n_steps=T))
    results = {(r.user, r.jid): r for r in eng.run()}
    resumed = results[("ua", 2)]
    assert resumed.start_step == 2 and resumed.n_steps == T
    assert len(resumed.records) == T

    st = strat.init_state(jax.tree.map(jnp.copy, base), MZ)
    us = np.uint32(derive_user_seed(ctg.ENGINE_SEED, "ua"))
    bs = ctg.make_batches(cfg, "ua", T)
    for t in range(T):
        st, _ = strat.step(model.loss, st, bs[t],
                           zrng.fold_seed(jnp.uint32(us), t), MZ)
    _assert_trees_equal(store.materialize("ua"), st.params, what="ua:")


def test_crash_recovery_from_replay_log(tmp_path):
    """Fault injection: flush to the per-user log file, lose the engine
    AND the store, rebuild both from the log alone, finish the job --
    final params bit-equal an uninterrupted run's."""
    cfg = _dense_cfg()
    model = build_model(cfg)
    strat = build_strategy("fused", "sgd")
    base = _fresh_base(cfg)
    T, log_dir = 5, str(tmp_path / "logs")

    store1 = AdapterStore(jax.tree.map(jnp.copy, base), mezo_cfg=MZ)
    eng1 = TrainEngine(cfg, store1, n_slots=1, seed=ctg.ENGINE_SEED,
                       log_dir=log_dir)
    eng1.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", T),
                         n_steps=T))
    eng1.step(); eng1.step(); eng1.step()
    eng1.evict("u")
    del eng1, store1                       # "crash": only the log survives

    store2 = AdapterStore(jax.tree.map(jnp.copy, base), mezo_cfg=MZ)
    store2.load("u", os.path.join(log_dir, "u.jsonl"))
    assert len(store2.records("u")) == 3      # the pre-crash flush survived
    eng2 = TrainEngine(cfg, store2, n_slots=1, seed=ctg.ENGINE_SEED,
                       log_dir=log_dir)
    eng2.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", T),
                         n_steps=T))
    (res,) = eng2.run()
    assert res.start_step == 3 and res.n_steps == T

    st = strat.init_state(jax.tree.map(jnp.copy, base), MZ)
    us = np.uint32(derive_user_seed(ctg.ENGINE_SEED, "u"))
    bs = ctg.make_batches(cfg, "u", T)
    for t in range(T):
        st, _ = strat.step(model.loss, st, bs[t],
                           zrng.fold_seed(jnp.uint32(us), t), MZ)
    _assert_trees_equal(store2.materialize("u"), st.params, what="u:")
    # the log file now carries the full uninterrupted-equivalent stream
    from repro.checkpoint.replay_log import ReplayLog
    assert [r["step"] for r in ReplayLog.read(
        os.path.join(log_dir, "u.jsonl"))] == list(range(T))


# ---------------------------------------------------------------------------
# admission guardrails


def test_duplicate_user_stays_queued():
    """One slot per user at a time: a second job for a resident user
    waits for the first to finish, then resumes from its records."""
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=4, seed=ctg.ENGINE_SEED)
    eng.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", 2),
                        n_steps=2))
    eng.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", 4),
                        n_steps=4))
    results = eng.run()
    assert [(r.jid, r.start_step, r.n_steps) for r in results] == \
        [(0, 0, 2), (1, 2, 4)]


def test_seed_collision_raises():
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=2, seed=ctg.ENGINE_SEED)
    eng.submit(TrainJob(user="a", batches=ctg.make_batches(cfg, "a", 2),
                        n_steps=2, seed=123))
    eng.submit(TrainJob(user="b", batches=ctg.make_batches(cfg, "b", 2),
                        n_steps=2, seed=123))
    with pytest.raises(ValueError, match="seed collision"):
        eng.run()


def test_walk_estimator_rejected():
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)
    with pytest.raises(ValueError, match="pristine"):
        TrainEngine(cfg, store, estimator="walk")


def test_update_rule_mismatch_rejected():
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)  # sgd store
    with pytest.raises(ValueError, match="update rule"):
        TrainEngine(cfg, store, update="momentum")


def test_target_already_met_finishes_without_steps():
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)
    eng = TrainEngine(cfg, store, n_slots=1, seed=ctg.ENGINE_SEED)
    eng.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", 2),
                        n_steps=2))
    eng.run()
    eng.submit(TrainJob(user="u", batches=ctg.make_batches(cfg, "u", 2),
                        n_steps=2))           # target already met
    (res,) = eng.run()
    assert res.start_step == 2 and res.n_steps == 2 and res.losses == []


def test_delta_only_user_not_resumable():
    """A user known only by a lossy int8 delta cannot seed a fine-tune
    resume -- the store must refuse, not silently fork the trajectory."""
    cfg = _dense_cfg()
    store = AdapterStore(_fresh_base(cfg), mezo_cfg=MZ)
    store.put_delta("u", [])                  # content irrelevant
    with pytest.raises(ValueError, match="lossy"):
        store.materialize_state("u")


# ---------------------------------------------------------------------------
# the one-call wrapper


def test_train_multi_tenant_wrapper():
    cfg = _dense_cfg()
    jobs = [TrainJob(user=f"u{i}",
                     batches=ctg.make_batches(cfg, f"u{i}", 2), n_steps=2)
            for i in range(3)]
    engine, results = train_multi_tenant(
        cfg, jobs, n_slots=2, seed=ctg.ENGINE_SEED, mezo_cfg=MZ,
        quant="int8", log_fn=lambda s: None)
    assert engine.stats.finished == 3
    assert sorted(r.user for r in results) == ["u0", "u1", "u2"]
    assert all(len(r.losses) == 2 for r in results)
