"""End-to-end behaviour: the paper's claims on this system, in miniature.

1. MeZO fine-tunes an LM and the loss descends (Figure 1 shape).
2. MeZO's training state beyond params is zero bytes; Adam's is 3x params
   (Table 1's mechanism).
3. Fine-tune -> serve roundtrip works (the personalized-LLM story).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import lm_batches
from repro.models import build_model
from repro.optim.adam import adam_init
from repro.runtime import Trainer, TrainerConfig


def test_mezo_finetunes_lm_loss_descends():
    cfg = get_config("opt-1.3b").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab=128)
    tc = TrainerConfig(optimizer="mezo",
                       mezo=MezoConfig(eps=1e-2, lr=1e-2, n_directions=8),
                       n_steps=100, log_every=1000)
    tr = Trainer(cfg, tc, lm_batches(8, 32, cfg.vocab, seed=1))
    tr.train()
    first = np.mean(tr.losses[:10])
    last = np.mean(tr.losses[-10:])
    assert last < first - 0.03, (first, last)


def test_optimizer_state_memory_contrast():
    cfg = get_config("opt-1.3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    a_bytes = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(adam_init(params)))
    assert a_bytes >= 2 * p_bytes          # two fp32 moments
    # MeZO state = the MezoConfig scalars; nothing param-shaped


def test_finetune_then_serve():
    from repro.launch.serve import serve
    cfg = get_config("gemma-2b").reduced()
    tc = TrainerConfig(optimizer="mezo",
                       mezo=MezoConfig(eps=1e-2, lr=1e-3, n_directions=1),
                       n_steps=3, log_every=1000)
    tr = Trainer(cfg, tc, lm_batches(4, 16, cfg.vocab, seed=0))
    params = tr.train()
    toks = serve(cfg, params, np.zeros((2, 4), np.int32), gen=3)
    assert toks.shape == (2, 3)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_examples_multipod_directions_subprocess():
    """The Sec-6.3 demonstration runs end-to-end on an 8-device mesh."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "examples/multipod_directions.py"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900)
    assert "OK: direction-parallel" in r.stdout, r.stdout + r.stderr
