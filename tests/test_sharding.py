"""Sharding rules: TP/EP placements, divisibility fitting, cache layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model, sharding as shd


@pytest.fixture(scope="module")
def mesh():
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    return Mesh(devs, ("data", "model"))


def _specs_for(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return shapes, shd.spec_tree(shapes)


def test_attention_tp_rules():
    shapes, specs = _specs_for("qwen3-4b")
    blk = specs["blocks"]
    assert blk["attn"]["wq"]["w"] == P(None, None, "model")
    assert blk["attn"]["wo"]["w"] == P(None, "model", None)
    assert blk["ln_attn"]["scale"] == P()
    assert specs["embed"]["tok"] == P("model", None)


def test_moe_ep_rules():
    shapes, specs = _specs_for("granite-moe-1b-a400m")
    blk = specs["blocks"]
    assert blk["moe"]["w_in"] == P(None, "model", None, None, None)
    assert blk["moe"]["w_out"] == P(None, "model", None, None)
    assert blk["moe"]["router"] == P(None, None, None)


def test_rwkv_rules():
    shapes, specs = _specs_for("rwkv6-7b")
    blk = specs["blocks"]
    assert blk["tm"]["wr"]["w"] == P(None, None, "model")
    assert blk["tm"]["wo"]["w"] == P(None, "model", None)


def test_fit_spec_odd_vocab(mesh):
    # granite's 49155 vocab cannot shard 4 ways -> replicated
    s = shd.fit_spec((49155, 64), P("model", None), mesh)
    assert s == P(None, None)
    s2 = shd.fit_spec((49156, 64), P("model", None), mesh)
    assert s2 == P("model", None)


def test_cache_spec_kv_and_state(mesh):
    cfg = get_config("jamba-v0.1-52b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(8, 64))
    cs = shd.cache_spec(cache, mesh)["blocks"]
    kv = cs[f"sub_{cfg.attn_index}"]["attn"]
    assert kv["k"][1] == "data"          # batch
    assert kv["k"][2] == "model"         # sequence-parallel cache
    mam = cs["sub_0"]["mamba"]
    assert mam["conv"][1] == "data"      # batch (unified axis 1)
    assert mam["ssm"][2] == "model"      # d_inner


def test_cache_spec_batch1_spills_seq_to_data(mesh):
    cfg = get_config("jamba-v0.1-52b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 1024))
    cs = shd.cache_spec(cache, mesh)["blocks"]
    # batch=1: seq axis takes both mesh axes
    assert cs[f"sub_{cfg.attn_index}"]["attn"]["k"][2] == ("model", "data")


def test_maybe_shard_is_noop_without_mesh():
    x = jnp.ones((8, 8))
    y = shd.maybe_shard(x, "model", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
