"""Perturbed-forward execution context (the fused MeZO path).

The sequential ``mezo_step`` realizes theta ± eps*z with three full
parameter sweeps per direction (perturb / counter-perturb / restore), and
``mezo_step_vmapdir`` with one transient param-sized copy. The fused path
removes both: the *unperturbed* params flow into the forward together with
a :class:`PerturbCtx` carrying ``(seed, coeff, dist)``, and each consumer
applies its leaf's perturbation at the point of use --

  * dense projections (QKV/O, MLP up/down, LM head) compute
    ``X @ (W + coeff*z)`` via the fused Pallas kernel
    ``repro.kernels.ops.zo_matmul`` (z regenerated tile-wise in VMEM,
    zero HBM bytes) or, on non-aligned shapes / without ``use_kernel``,
    via a transient jnp materialization that XLA fuses into the matmul;
  * embedding gathers perturb only the gathered rows
    (``rng.z_rows``: O(tokens*d), never O(vocab*d));
  * small leaves (norm scales, biases) add a transient ``coeff*z``.

Quantized bases (optim/quant.py): every primitive accepts a
``QuantizedLeaf`` in place of an array -- dense projections fuse the
int8 dequant into the same ``zo_matmul`` kernel pass
(``X @ (q*scale + coeff*z)``), embedding gathers dequantize only the
gathered rows, and the jnp fallback computes
``q*scale (+ delta) + coeff*z`` in one transient f32 expression. The
salt is the *leaf's* path (never ``.../q``), so the z-fields match the
f32 base's bit-for-bit.

Bit-compatibility contract: salts are derived from the same pytree path
strings as ``core.perturb._path_str``, and scan-stacked ``(L, ...)``
block leaves are handled by folding the layer index into a pre-hashed
base (``rng.leaf_base`` / ``rng.fold_leading``) with ``prime_offset=1``.
So for every leaf the fused forward sees *exactly* the z-field that
``add_scaled_z`` (and therefore ``spsa_gradient_estimate`` and the
replay-log checkpointer) would apply to the stacked parameter tree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import rng as zrng
from repro.core.perturb import _path_str, is_perturbable, kernel_aligned
from repro.optim.quant import is_quantized, take_rows, take_rows_f32

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PerturbCtx:
    """theta + coeff * z(seed), applied lazily at each parameter's use site.

    seed/coeff may be traced (they are scan/vmap-carried in the fused MeZO
    step); dist / use_kernel / prefix are trace-time static.

    **User-axis mode**: a (U,) ``seed`` vector (``coeff`` scalar or (U,))
    batches the ctx over a leading user axis -- B users' directions in
    one forward. Input conventions then follow the multi-tenant state
    layout (``core.batching``): activations and plain param leaves carry
    a leading user axis; :class:`~repro.optim.quant.QuantizedLeaf`
    weights keep the single resident int8 base (``q``/``scale`` shared)
    with only the f32 ``delta`` stacked (or absent when frozen). Aligned
    shared-base matmuls dispatch ONE ``kernels.ops.zo_matmul_users``
    call per site -- per-user seeds/coeffs ride SMEM while the base
    tiles are read once -- and every other primitive vmaps the scalar
    path, so each lane is bit-identical to a scalar ctx with that
    user's (seed, coeff).
    """
    seed: Any                        # uint32 step/direction seed; (U,) =>
    #                                  user-axis mode (see class docstring)
    coeff: Any                       # f32 scalar: +eps or -eps
    dist: str = "rademacher"
    use_kernel: bool = False         # route aligned 2-D matmuls via Pallas
    prefix: str = ""                 # pytree path of the current scope
    layer: Optional[Any] = None      # leading (scan) index into stacked leaves

    # -- scope plumbing ----------------------------------------------------

    def scope(self, name: str) -> "PerturbCtx":
        """Descend into a param sub-dict (extends the salt path)."""
        p = f"{self.prefix}/{name}" if self.prefix else name
        return dataclasses.replace(self, prefix=p)

    def at_layer(self, idx) -> "PerturbCtx":
        """Bind the leading scan index of stacked (L, ...) leaves."""
        return dataclasses.replace(self, layer=jnp.asarray(idx, jnp.uint32))

    def _leaf(self, name: str):
        """(full path, pre-hashed base, prime offset) for a named leaf."""
        path = f"{self.prefix}/{name}" if self.prefix else name
        base = zrng.leaf_base(self.seed, zrng.leaf_salt(path))
        off = 0
        if self.layer is not None:
            base = zrng.fold_leading(base, self.layer, dim=0)
            off = 1
        return path, base, off

    def _coeff(self):
        return jnp.asarray(self.coeff, jnp.float32)

    # -- user axis ---------------------------------------------------------

    @property
    def batched(self) -> bool:
        """True in user-axis mode ((U,) seed vector)."""
        return jnp.ndim(self.seed) == 1

    def _user_lanes(self):
        """(U,) uint32 seeds and (U,) f32 coeffs (scalar coeff broadcast)."""
        seeds = jnp.asarray(self.seed, jnp.uint32)
        coeffs = jnp.broadcast_to(
            jnp.asarray(self.coeff, jnp.float32), seeds.shape)
        return seeds, coeffs

    def _lane(self, seed, coeff) -> "PerturbCtx":
        return dataclasses.replace(self, seed=seed, coeff=coeff)

    @staticmethod
    def _user_axes(leaf):
        """vmap in_axes for a weight under the user-axis conventions:
        plain leaves stacked on axis 0 unless shared 2-D; quantized
        leaves share the base and stack only a present delta."""
        from repro.optim.quant import QuantizedLeaf
        if is_quantized(leaf):
            return QuantizedLeaf(q=None, scale=None,
                                 delta=None if leaf.delta is None else 0,
                                 orig_dtype=leaf.orig_dtype)
        return 0

    # -- perturbation primitives ------------------------------------------

    def perturb(self, name: str, leaf):
        """leaf + coeff*z, transient (the jnp fallback for any leaf).

        Quantized leaves dequantize into the same transient:
        ``q*scale (+ delta) + coeff*z`` in one f32 expression, with the
        z-field of the *leaf's* path (identical to the f32 base's).

        User-axis mode: ``leaf`` is per-user stacked (quantized: shared
        base, stacked delta); each lane gets its own z-field."""
        if self.batched:
            seeds, coeffs = self._user_lanes()
            return jax.vmap(
                lambda s, c, lf: self._lane(s, c).perturb(name, lf),
                in_axes=(0, 0, self._user_axes(leaf)))(seeds, coeffs, leaf)
        path, base, off = self._leaf(name)
        if not is_perturbable(path) or \
                not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.dequantize() if is_quantized(leaf) else leaf
        z = zrng.z_field(None, 0, leaf.shape, jnp.float32, self.dist,
                         prime_offset=off, base=base)
        lf = leaf.dequantize_f32() if is_quantized(leaf) \
            else leaf.astype(jnp.float32)
        return (lf + self._coeff() * z).astype(leaf.dtype)

    def matmul(self, x, w, name: str = "w"):
        """x @ (w + coeff*z) for x (..., K), w (K, N).

        MXU-aligned 2-D weights go through the fused Pallas kernel (z never
        leaves VMEM); everything else falls back to a transient jnp
        materialization with identical values (ref.zo_matmul_ref semantics,
        cast back to the weight dtype like ``add_scaled_z`` so the f32 path
        is bit-exact with the sequential strategies).
        """
        if self.batched:
            return self._matmul_users(x, w, name)
        path, base, off = self._leaf(name)
        if not is_perturbable(path) or \
                not jnp.issubdtype(w.dtype, jnp.floating):
            return x @ (w.dequantize() if is_quantized(w) else w)
        k, n = w.shape
        if self.use_kernel and kernel_aligned(w.shape) and \
                not (is_quantized(w) and w.delta is not None):
            from repro.kernels import ops as kops  # lazy: pallas import
            lead = x.shape[:-1]
            if is_quantized(w):
                # dequant fused into the same kernel tile pass:
                # X @ (q*scale + coeff*z), base resident as int8
                y = kops.zo_matmul(x.reshape(-1, k), w.q, base, 0,
                                   self._coeff(), dist=self.dist,
                                   prime_offset=off, prehashed=True,
                                   scale=w.scale)
            else:
                y = kops.zo_matmul(x.reshape(-1, k), w, base, 0,
                                   self._coeff(), dist=self.dist,
                                   prime_offset=off, prehashed=True)
            return y.reshape(*lead, n)
        return x @ self.perturb(name, w)

    def _matmul_users(self, x, w, name: str):
        """User-axis matmul: x (U, ..., K). A SHARED 2-D base (plain f32
        or delta-less quantized) on the aligned kernel path dispatches
        one :func:`repro.kernels.ops.zo_matmul_users` -- B users'
        perturbed forwards reading the resident base once; stacked /
        delta-carrying weights vmap the scalar lane (bit-identical to a
        per-user loop either way)."""
        path, base, off = self._leaf(name)   # base: (U,) lane vector
        seeds, coeffs = self._user_lanes()
        shared = (w.delta is None and w.q.ndim == 2) if is_quantized(w) \
            else (w.ndim == 2)
        floating = jnp.issubdtype(w.dtype, jnp.floating)
        wshape = w.q.shape if is_quantized(w) else w.shape
        if shared and floating and is_perturbable(path) and \
                self.use_kernel and kernel_aligned(wshape):
            from repro.kernels import ops as kops  # lazy: pallas import
            u, lead, k = x.shape[0], x.shape[1:-1], x.shape[-1]
            n = wshape[-1]
            if is_quantized(w):
                y = kops.zo_matmul_users(x.reshape(u, -1, k), w.q, base, 0,
                                         coeffs, dist=self.dist,
                                         prime_offset=off, prehashed=True,
                                         scale=w.scale)
            else:
                y = kops.zo_matmul_users(x.reshape(u, -1, k), w, base, 0,
                                         coeffs, dist=self.dist,
                                         prime_offset=off, prehashed=True)
            return y.reshape(u, *lead, n)
        w_ax = None if (shared and not is_quantized(w)) \
            else self._user_axes(w)
        return jax.vmap(
            lambda s, c, xu, wu: self._lane(s, c).matmul(xu, wu, name),
            in_axes=(0, 0, 0, w_ax))(seeds, coeffs, x, w)

    def take(self, name: str, table, ids):
        """take(table + coeff*z, ids, axis=0), perturbing only gathered
        rows. A quantized table dequantizes only the gathered rows too
        (quant.take_rows): still O(tokens*d), never O(vocab*d).

        User-axis mode: ``ids`` carry a leading user axis; the table
        follows the weight conventions (stacked plain / shared base)."""
        if self.batched:
            seeds, coeffs = self._user_lanes()
            return jax.vmap(
                lambda s, c, tb, i: self._lane(s, c).take(name, tb, i),
                in_axes=(0, 0, self._user_axes(table), 0))(
                seeds, coeffs, table, ids)
        path, base, off = self._leaf(name)
        if not is_perturbable(path) or \
                not jnp.issubdtype(table.dtype, jnp.floating):
            return take_rows(table, ids)
        rows = take_rows_f32(table, ids)
        z = zrng.z_rows(base, ids, table.shape[1], jnp.float32, self.dist,
                        prime_offset=off)
        return (rows + self._coeff() * z).astype(table.dtype)

    def materialize(self, subtree: PyTree, name: str = "") -> PyTree:
        """Perturb every leaf of a param subtree transiently.

        Generic fallback for components without a per-leaf fused path --
        today only MoE expert sub-dicts (stacked 3/4-D leaves consumed
        inside sort-based dispatch) -- and, scoped at the root, the
        parity oracle the tests evaluate the fused forward against.
        Equivalent to ``add_scaled_z`` restricted to the subtree: one
        transient copy of the subtree, no walk sweeps.
        """
        ctx = self.scope(name) if name else self
        leaves, treedef = jax.tree_util.tree_flatten_with_path(
            subtree, is_leaf=is_quantized)
        out = [ctx.perturb(_path_str(p), leaf) for p, leaf in leaves]
        return jax.tree_util.tree_unflatten(treedef, out)


def sub(ctx: Optional[PerturbCtx], name: str) -> Optional[PerturbCtx]:
    """ctx.scope(name), passing None through (unperturbed forward)."""
    return None if ctx is None else ctx.scope(name)
