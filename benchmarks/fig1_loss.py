"""Paper Figure 1: training-loss curves, MeZO vs Adam fine-tuning.

RoBERTa-family reduced model on synthetic SST-2. The expected shape (and
the paper's observation): both descend; Adam descends faster per step;
MeZO descends "slightly but steadily".
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.configs import get_config
from repro.core import MezoConfig
from repro.data.synthetic import sst2_batches
from repro.optim.adam import AdamConfig
from repro.runtime import Trainer, TrainerConfig


def run(out_dir="experiments/bench"):
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config("roberta-large").reduced(n_layers=2, d_model=128,
                                              d_ff=256, vocab=256)
    steps = 200
    curves = {}
    rows = []
    # MeZO hypers from a short stability sweep: higher lr diverges on the
    # binary-CE head (lr=1e-2 blew up to loss 19.8); the paper's own
    # observation is "decreases slightly but steadily", which this shows
    for opt, oc in (("mezo", dict(mezo=MezoConfig(eps=1e-3, lr=2e-3,
                                                  n_directions=8))),
                    ("adam", dict(adam=AdamConfig(lr=1e-3)))):
        tc = TrainerConfig(optimizer=opt, n_steps=steps, log_every=1000,
                           **oc)
        tr = Trainer(cfg, tc, sst2_batches(16, 32, cfg.vocab, seed=5))
        t0 = time.perf_counter()
        tr.train()
        us = (time.perf_counter() - t0) / steps * 1e6
        curves[opt] = tr.losses
        d0, d1 = np.mean(tr.losses[:10]), np.mean(tr.losses[-10:])
        rows.append((f"fig1/{opt}", us, f"loss {d0:.3f}->{d1:.3f}"))

    with open(os.path.join(out_dir, "fig1_loss.json"), "w") as f:
        json.dump(curves, f)
    # the paper's qualitative claims, asserted
    m0, m1 = np.mean(curves["mezo"][:20]), np.mean(curves["mezo"][-20:])
    a0, a1 = np.mean(curves["adam"][:20]), np.mean(curves["adam"][-20:])
    assert m1 < m0, "MeZO loss should decrease (Fig 1)"
    assert a1 < a0, "Adam loss should decrease (Fig 1)"
    rows.append(("fig1/adam_faster_per_step", 0.0,
                 f"adam_drop={a0-a1:.3f};mezo_drop={m0-m1:.3f}"))
    return rows
