"""Adam / SGD baselines -- the derivative-based arm PocketLLM compares
against (Table 1/2: Adam OOMs at batch 64 on the phone; MeZO does not).

State is kept in fp32 (two moments), matching the memory model the paper's
argument rests on: Adam memory = params + grads + 2x fp32 moments
(+ activations linear in batch). ``memory_analysis`` of this step vs the
MeZO step is our Table-1 reproduction at TPU scale.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0          # 0 = off
    compress_grads: bool = False    # int8 all-reduce (optim/compression.py)


@dataclasses.dataclass
class AdamState:
    mu: PyTree
    nu: PyTree
    count: jnp.ndarray


jax.tree_util.register_pytree_node(
    AdamState,
    lambda s: ((s.mu, s.nu, s.count), None),
    lambda _, c: AdamState(*c),
)


def _float_leaves_map(f, *trees):
    def g(p, *rest):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return f(p, *rest)
        return p
    return jax.tree.map(g, *trees)


def adam_init(params: PyTree) -> AdamState:
    zeros = _float_leaves_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                     count=jnp.zeros((), jnp.int32))


def adam_update(params: PyTree, grads: PyTree, state: AdamState,
                cfg: AdamConfig):
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    if cfg.grad_clip:
        gnorm = jnp.sqrt(sum(jnp.vdot(g.astype(jnp.float32), g.astype(jnp.float32))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v
                      + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)

    def upd(p, m, v):
        step = cfg.lr * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    params = _float_leaves_map(upd, params, mu, nu)
    return params, AdamState(mu=mu, nu=nu, count=count)


@partial(jax.jit, static_argnames=("loss_fn", "cfg"), donate_argnums=(1, 3))
def grad_train_step(loss_fn: Callable, params: PyTree, batch: Any,
                    state: AdamState, cfg: AdamConfig):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    if cfg.compress_grads:
        from repro.optim.compression import int8_compress_tree
        grads = int8_compress_tree(grads)
    params, state = adam_update(params, grads, state, cfg)
    return params, state, loss


@partial(jax.jit, static_argnames=("loss_fn", "lr"), donate_argnums=(1,))
def sgd_train_step(loss_fn: Callable, params: PyTree, batch: Any,
                   lr: float = 1e-4):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    params = _float_leaves_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return params, loss
