"""Capture golden parity values for the block-registry runtime refactor.

Run ONCE at the pre-refactor seed (PR 3 tree) to pin forward logits, loss
scalars, and greedy decode tokens of every family; the parity suite
(tests/test_runtime_parity.py) then holds the refactored runtime to these
values. Re-running after the refactor must reproduce the same file --
regenerate only if a deliberate numerical change lands, and say so in the
commit that does.

    PYTHONPATH=src JAX_PLATFORMS=cpu python tests/golden/capture_goldens.py
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import PerturbCtx
from repro.models import build_model

# one representative arch per family (reduced configs run f32 on CPU)
FAMILY_ARCHS = {
    "dense": "gemma-2b",
    "moe": "granite-moe-1b-a400m",
    "hybrid": "jamba-v0.1-52b",
    "ssm": "rwkv6-7b",
    "encdec": "whisper-base",
}

B, S, GEN = 2, 16, 8
SEED, EPS = 9, 1e-3


def make_batch(cfg, key):
    kt, kg = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(kg, (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.enc_len, cfg.d_model))
    return batch


def capture(arch: str) -> dict:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    rec = {"arch": arch, "family": cfg.family}
    rec["param_l1"] = float(sum(
        jnp.sum(jnp.abs(leaf.astype(jnp.float32)))
        for leaf in jax.tree.leaves(params)))

    logits, _ = model.forward(params, batch)
    rec["logits_last"] = np.asarray(logits[:, -1, :], np.float32).tolist()
    rec["logits_mean"] = float(jnp.mean(logits.astype(jnp.float32)))
    rec["logits_absum"] = float(jnp.sum(jnp.abs(logits.astype(jnp.float32))))

    rec["loss"] = float(model.loss(params, batch))
    ctx = PerturbCtx(seed=jnp.uint32(SEED), coeff=jnp.float32(EPS))
    rec["loss_perturbed"] = float(model.loss(params, batch, perturb=ctx))

    # greedy decode through decode_step only (prompt fed token by token)
    cache = model.init_cache(B, S + GEN)
    toks = batch["tokens"]
    out = []
    last = None
    for t in range(S + GEN - 1):
        cur = toks[:, t:t + 1] if t < S else last
        if t >= S:
            out.append(np.asarray(cur))
        lg, cache = model.decode_step(params, cache, cur, jnp.int32(t))
        last = jnp.argmax(lg[:, -1:, :], axis=-1).astype(jnp.int32)
    out.append(np.asarray(last))
    rec["greedy_tokens"] = np.concatenate(
        out, axis=1)[:, :GEN].astype(int).tolist()

    if model.prefill is not None:
        cache = model.init_cache(B, S + GEN)
        plg, _ = model.prefill(params, cache, toks)
        rec["prefill_logits_last"] = np.asarray(
            plg[:, -1, :], np.float32).tolist()
    return rec


def main():
    out = {arch: capture(arch) for arch in FAMILY_ARCHS.values()}
    path = os.path.join(os.path.dirname(__file__), "runtime_parity.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
