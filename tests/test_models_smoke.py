"""Per-architecture smoke tests (required deliverable f).

Each assigned arch instantiates a REDUCED config of the same family and
runs one forward + one MeZO train step + two decode steps on CPU,
asserting output shapes and no NaNs. Full configs are exercised only by
the dry-run.

Set REPRO_FAMILY=<family[,family]> to restrict the parametrized tests
to those families -- the CI family-matrix job runs one job per family so
a regression names itself in the job list.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ARCHS, get_config
from repro.core import MezoConfig, mezo_step
from repro.models import build_model

_FAM = os.environ.get("REPRO_FAMILY")
SMOKE_ARCHS = [a for a in ALL_ARCHS
               if not _FAM or get_config(a).family in _FAM.split(",")]

B, S = 2, 16


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_len, cfg.d_model))
    if cfg.num_patches:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    if cfg.n_classes:
        batch["label"] = jnp.arange(B) % cfg.n_classes
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    logits, aux = model.forward(params, batch)
    if cfg.n_classes:
        assert logits.shape == (B, cfg.n_classes)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss0 = float(model.loss(params, batch))
    assert np.isfinite(loss0)

    p2, maux = mezo_step(model.loss, jax.tree.map(jnp.copy, params), batch,
                         jnp.uint32(0), MezoConfig(eps=1e-3, lr=1e-4))
    assert np.isfinite(float(maux.loss))
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in SMOKE_ARCHS
                                  if get_config(a).family != "encoder"])
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 8)
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, cache = model.decode_step(params, cache, tok, jnp.int32(0))
    lg, cache = model.decode_step(params, cache, tok, jnp.int32(1))
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ("qwen3-4b", "gemma-2b",
                                              "rwkv6-7b", "jamba-v0.1-52b",
                                              "granite-moe-1b-a400m")
                                  if a in SMOKE_ARCHS])
def test_decode_matches_forward(arch):
    """Step-by-step decode must reproduce the full-sequence forward."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity semantics differ between T=B*S and T=B token batches;
        # use generous capacity so nothing is dropped either way
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    T = 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    full_logits, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(B, T)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_forward_last_only_matches_full():
    cfg = get_config("qwen3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full, _ = model.forward(params, {"tokens": toks})
    last, _ = model.forward(params, {"tokens": toks}, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-4, atol=1e-5)


def test_assigned_configs_exact_values():
    """The 10 assigned architectures carry the exact assigned dims."""
    expect = {
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }
    for arch, (nl, dm, nh, kv, dff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == nl and cfg.d_model == dm, arch
        assert cfg.n_heads == nh and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == dff and cfg.vocab == v, arch
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("kimi-k2-1t-a32b").topk == 8
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("jamba-v0.1-52b").n_experts == 16
    assert get_config("gemma-2b").head_dim == 256
    assert get_config("qwen3-4b").qk_norm
