"""Direction-parallel ZO training across pods -- PocketLLM Sec 6.3 realized.

Runs in a subprocess-fresh interpreter with 8 placeholder devices forming
a (pod=2, data=2, model=2) mini production mesh, and demonstrates:

  1. K perturbation directions evaluated concurrently (vmap axis sharded
     over the pod axis),
  2. cross-pod traffic = the (K,) scalar vector gs (inspect the HLO:
     the only cross-pod collective is scalar-sized),
  3. straggler drop: masking one pod's direction yields a valid update,
  4. elastic: "losing a pod" = halving K; no parameter resharding.

  PYTHONPATH=src python examples/multipod_directions.py
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import MezoConfig, get_strategy, mezo_step_vmapdir
from repro.data.synthetic import lm_batch_at, synthetic_lm_corpus
from repro.models import build_model, sharding as shd
from repro.roofline.hlo import collective_bytes


def main():
    # axis_types / set_mesh only exist on newer jax; all shardings below
    # are explicit NamedShardings, so older versions run without them
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    else:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("qwen3-4b").reduced(d_model=64, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, shd.sharding_tree(params, mesh))

    stream = synthetic_lm_corpus(8 * 40 * 33, cfg.vocab, 0)
    batch = {k: jax.device_put(
        jnp.asarray(v), NamedSharding(mesh, P("data")))
        for k, v in lm_batch_at(0, 8, 32, cfg.vocab, stream).items()}

    mcfg = MezoConfig(eps=1e-2, lr=1e-2, n_directions=2)  # 1 per pod

    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
        strat = get_strategy("mezo-parallel")
        lowered = strat.lower(model.loss, strat.init_state(params, mcfg),
                              batch, jnp.uint32(0), mcfg, None)
        hlo = lowered.compile().as_text()
        coll = collective_bytes(hlo)
        p2, aux = mezo_step_vmapdir(model.loss, params, batch,
                                    jnp.uint32(0), mcfg)
        # straggler: drop direction 1 (pod 1 late) -- still a valid step
        p3, _ = mezo_step_vmapdir(model.loss, params, batch, jnp.uint32(0),
                                  mcfg, jnp.array([1.0, 0.0]))
        # elastic: pod left -> K=1, same params sharding, no resharding
        mcfg1 = MezoConfig(eps=1e-2, lr=1e-2, n_directions=1)
        p4, _ = mezo_step_vmapdir(model.loss, params, batch, jnp.uint32(0),
                                  mcfg1)

    print(f"gs per direction: {np.asarray(aux.gs)}")
    print(f"collective bytes/step/device: {coll.get('total', 0):,} "
          f"(params: {sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params)):,} bytes)")
    print("straggler-masked update == K=1 update:",
          np.allclose(np.asarray(p3['ln_f']['scale']),
                      np.asarray(p4['ln_f']['scale']), atol=1e-6))
    assert np.isfinite(np.asarray(aux.gs)).all()
    print("OK: direction-parallel, straggler drop and elastic-K all work")


if __name__ == "__main__":
    main()
