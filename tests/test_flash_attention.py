"""Flash-attention kernel vs the chunked/plain jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import attention

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, t, h, kv, hd, dtype=jnp.float32):
    q = jax.random.normal(KEY, (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, kv, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, kv, hd),
                          jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (1, 64, 4, 2, 16),    # GQA g=2
    (2, 128, 8, 1, 32),   # MQA
    (1, 96, 4, 4, 16),    # MHA, non-128 seq
])
def test_flash_matches_reference(shape, causal):
    b, s, h, kv, hd = shape
    q, k, v = _qkv(b, s, s, h, kv, hd)
    got = flash_attention(q, k, v, causal=causal, blocks=(32, 32),
                          interpret=True)
    want = attention(q, k, v, causal=causal, chunk=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_block_invariance():
    q, k, v = _qkv(1, 64, 64, 4, 2, 16)
    a = flash_attention(q, k, v, causal=True, blocks=(64, 64),
                        interpret=True)
    b = flash_attention(q, k, v, causal=True, blocks=(16, 32),
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 64, 64, 4, 2, 32, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, blocks=(32, 32),
                          interpret=True)
    want = attention(q, k, v, causal=True, chunk=0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_matches_chunked_path():
    q, k, v = _qkv(1, 128, 128, 4, 4, 16)
    got = flash_attention(q, k, v, causal=True, blocks=(32, 64),
                          interpret=True)
    want = attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_long_context_block_skipping():
    """Causal tiles above the diagonal are masked; long-T correctness."""
    q, k, v = _qkv(1, 32, 256, 4, 4, 16)   # decode-ish: S << T
    got = flash_attention(q, k, v, causal=False, blocks=(32, 64),
                          interpret=True)
    want = attention(q, k, v, causal=False, chunk=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_selectable_in_model_config():
    """attn_impl='flash' produces the same logits as the chunked path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen3-4b").reduced()
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, cfg.vocab)
    m_ref = build_model(cfg)
    m_fl = build_model(dataclasses.replace(cfg, attn_impl="flash"))
    p = m_ref.init(jax.random.PRNGKey(1))
    a, _ = m_ref.forward(p, {"tokens": toks})
    b, _ = m_fl.forward(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-3, atol=2e-3)
