"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep (pip install -e .[test]); tier-1 runs without")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import rng as zrng
from repro.core.mezo import _direction_coeffs
from repro.models.sharding import fit_spec
from repro.models.transformer import softmax_xent
from repro.optim.compression import int8_dequantize, int8_quantize
from jax.sharding import Mesh, PartitionSpec as P

SETTINGS = dict(max_examples=25, deadline=None)


@given(seed=st.integers(0, 2**32 - 1), salt=st.integers(0, 2**32 - 1),
       rows=st.integers(1, 40), cols=st.integers(1, 40),
       r0=st.integers(0, 1000), c0=st.integers(0, 1000))
@settings(**SETTINGS)
def test_rng_tile_equals_slice(seed, salt, rows, cols, r0, c0):
    """Any tile with offsets == the same slice of a bigger field."""
    full = zrng.z_field(jnp.uint32(seed), salt, (r0 + rows, c0 + cols))
    tile = zrng.z_field(jnp.uint32(seed), salt, (rows, cols),
                        offsets=(r0, c0))
    np.testing.assert_array_equal(np.asarray(full[r0:, c0:]),
                                  np.asarray(tile))


@given(k=st.integers(1, 16), lr=st.floats(1e-6, 1.0),
       data=st.data())
@settings(**SETTINGS)
def test_direction_coeffs_sum_preserved(k, lr, data):
    """Masked renormalization keeps |sum coeffs| == lr (unbiased scale)."""
    mask = np.array(data.draw(st.lists(st.sampled_from([0.0, 1.0]),
                                       min_size=k, max_size=k)), np.float32)
    coeffs = np.asarray(_direction_coeffs(k, jnp.float32(lr), mask))
    if mask.sum() == 0:
        return
    np.testing.assert_allclose(-coeffs.sum(), lr, rtol=1e-5)
    assert (coeffs[mask == 0] == 0).all()


@given(b=st.integers(1, 4), s=st.integers(1, 8), v=st.integers(2, 30),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_softmax_xent_matches_numpy(b, s, v, seed):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((b, s, v)).astype(np.float32) * 3
    targets = rng.integers(0, v, (b, s))
    got = float(softmax_xent(jnp.asarray(logits), jnp.asarray(targets)))
    ex = np.exp(logits - logits.max(-1, keepdims=True))
    p = ex / ex.sum(-1, keepdims=True)
    want = -np.log(np.take_along_axis(p, targets[..., None], -1)).mean()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
@settings(**SETTINGS)
def test_int8_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)).astype(np.float32) * scale)
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s)
    # error bounded by one quantization bucket
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) + 1e-6


@given(dim=st.integers(1, 64), nd=st.integers(1, 3),
       data=st.data())
@settings(max_examples=25, deadline=None)
def test_fit_spec_always_divides(dim, nd, data):
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    axes = data.draw(st.lists(st.sampled_from([None, "data", "model"]),
                              min_size=nd, max_size=nd, unique_by=id))
    shape = tuple(data.draw(st.integers(1, 64)) for _ in range(nd))
    spec = fit_spec(shape, P(*axes), mesh)
    sizes = {"data": 4, "model": 4}
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        prod = int(np.prod([sizes[n] for n in names]))
        assert shape[d] % prod == 0
