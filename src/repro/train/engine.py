"""Batched multi-tenant ZO TrainEngine: one dispatch advances B users.

The serving subsystem already holds thousands of users as replay-log
adapters over one resident base; this module is the trainer-side twin.
A fixed table of ``n_slots`` fine-tune slots shares ONE jitted
user-batched step (``ZOStrategy.step_users``): every per-user leaf of
the stacked :class:`~repro.core.engine.TrainState` carries a leading
slot axis, quantized leaves keep the single resident int8 base
(``q``/``scale`` shared, only the f32 deltas are per-slot), and each
engine step vmaps the fused perturbed forward over the slot axis — B
users' directions evaluated in one ``zo_matmul``-shaped dispatch.

Correctness spine (what every test pins):

* **bit-parity** — an active slot's trajectory (losses, gs, deltas,
  replay-log lines) is bit-identical to a lone sequential
  :class:`~repro.runtime.trainer.Trainer` run with the same per-user
  seed, because each vmap lane runs the exact sequential step arithmetic
  (traced per-lane eps/lr, true-division gs) and inactive lanes are
  masked back untouched (``core.batching.masked_merge``);
* **seed isolation** — per-user base seeds derive as
  ``fold_seed(engine_seed, crc32(user))`` (:func:`derive_user_seed`),
  per-step seeds as ``fold_seed(user_seed, step)``: a slot's z-streams
  depend only on (user, step, leaf), never on the slot index or on
  co-residents, so slot reassignment cannot reuse a stale seed;
* **evict/resume** — finishing or evicting a slot flushes its
  ``(seed, gs)`` records to the :class:`~repro.serve.adapters
  .AdapterStore` (and, with ``log_dir``, to a per-user replay-log
  JSONL); re-admission replays them through the update rule
  (``store.materialize_state``), which is bit-identical to never having
  been evicted — the same guarantee the checkpoint manager gives a
  crashed sequential run.

Jobs queue like serve requests: whenever a slot frees, the next job is
admitted mid-flight (its resume state scattered into the slot lane);
slots finish independently (ragged targets), so the engine never drains
the batch to admit new work.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Any, Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.replay_log import ReplayLog
from repro.core import rng as zrng
from repro.core.batching import install_user, stack_users
from repro.core.engine import TrainState, build_strategy
from repro.core.mezo import MezoConfig
from repro.models import build_model
from repro.serve.adapters import AdapterStore

PyTree = Any
#: a job's data: a sequence indexed by the user's GLOBAL step, or a
#: callable step -> batch (so a resumed job consumes exactly the batches
#: an uninterrupted run would have).
BatchSource = Union[Sequence[Any], Callable[[int], Any]]


def derive_user_seed(engine_seed: int, user: str) -> int:
    """Stable per-user base seed: ``fold_seed(engine_seed, crc32(user))``.

    A pure function of (engine_seed, user) — never of the slot index or
    admission order — which is what makes slot reassignment incapable of
    reusing a stale seed, and two engines with the same seed agree on
    every user's trajectory.
    """
    return int(np.asarray(zrng.fold_seed(
        jnp.uint32(engine_seed), jnp.uint32(zrng.leaf_salt(user)))))


@dataclasses.dataclass
class TrainJob:
    """One user's fine-tune job. ``n_steps`` is the user's TOTAL step
    target: a job resumed from k stored records runs ``n_steps - k``
    more steps (zero if already met), mirroring ``Trainer.n_steps``."""
    user: str
    batches: BatchSource
    n_steps: int
    seed: Optional[int] = None       # per-user base seed (default derived)
    lr: Optional[float] = None       # per-user override of cfg.lr
    eps: Optional[float] = None      # per-user override of cfg.eps
    jid: int = -1                    # assigned by submit()


@dataclasses.dataclass
class JobResult:
    user: str
    jid: int
    start_step: int                  # replayed records at admission
    n_steps: int                     # user-global steps completed
    losses: List[float]              # this residency's step losses
    records: List[dict]              # the user's FULL replay log
    evicted: bool = False


@dataclasses.dataclass
class TrainStats:
    dispatches: int = 0              # batched step_users calls
    user_steps: int = 0              # total user-steps advanced
    train_s: float = 0.0
    admitted: int = 0
    finished: int = 0
    evicted: int = 0

    @property
    def user_steps_per_s(self) -> float:
        return self.user_steps / self.train_s if self.train_s else 0.0


class TrainEngine:
    """Slot-table multi-tenant trainer over one AdapterStore base.

    The store is both job source (admission resumes from a user's
    records) and sink (finish/evict flushes the grown log back), so a
    user can bounce between training and serving — or between engines —
    with nothing but the scalar log travelling.
    """

    def __init__(self, model_cfg, store: AdapterStore, n_slots: int = 4,
                 estimator: str = "fused", update: str = "sgd",
                 seed: int = 0, mezo_cfg: Optional[MezoConfig] = None,
                 log_dir: Optional[str] = None):
        self.cfg = model_cfg
        self.model = build_model(model_cfg)
        self.store = store
        self.mz = mezo_cfg or store.cfg
        self.strategy = build_strategy(estimator, update)
        if not self.strategy.estimator.pristine:
            raise ValueError(
                f"TrainEngine requires a pristine direction estimator "
                f"(vmapdir/fused), got {estimator!r}: the in-place walk's "
                f"roundoff would break replay-log bit-parity on resume")
        if self.strategy.update.name != store.rule.name:
            raise ValueError(
                f"engine update rule {self.strategy.update.name!r} != "
                f"store rule {store.rule.name!r}: eviction would flush "
                f"records the store replays with different arithmetic")
        self.n_slots = n_slots
        self.seed = seed
        self.log_dir = log_dir
        self.stats = TrainStats()

        self.queue: deque = deque()
        self._next_jid = 0
        self._job: List[Optional[TrainJob]] = [None] * n_slots
        self._active = np.zeros(n_slots, bool)
        self._user_seed = np.zeros(n_slots, np.uint32)
        self._step = np.zeros(n_slots, np.int64)     # user-global step
        self._target = np.zeros(n_slots, np.int64)
        self._start = np.zeros(n_slots, np.int64)
        # kept as python floats (not np.float32): replay-log lines carry
        # these verbatim and must serialize byte-identically to the
        # sequential CheckpointManager's (which logs cfg.lr / cfg.eps)
        self._lr = [float(self.mz.lr)] * n_slots
        self._eps = [float(self.mz.eps)] * n_slots
        self._prior: List[List[dict]] = [[] for _ in range(n_slots)]
        # per-slot pending (step, seed, device gs, device loss) rows —
        # host sync deferred to flush so the hot loop stays async
        self._pending: List[list] = [[] for _ in range(n_slots)]
        self._results: List[JobResult] = []

        params, opt, _ = self.store.materialize_state(None)
        template = TrainState(params=params, step=jnp.uint32(0), opt=opt)
        self._state = stack_users([template] * n_slots)
        self._template_batch = None

    # ---- job lifecycle ---------------------------------------------------
    def submit(self, job: TrainJob) -> int:
        if job.n_steps < 0:
            raise ValueError("n_steps must be >= 0")
        job.jid = self._next_jid
        self._next_jid += 1
        self.queue.append(job)
        return job.jid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.n_slots) if not self._active[i]]

    def _resident_users(self):
        return {self._job[i].user for i in range(self.n_slots)
                if self._active[i]}

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                return
            if self.queue[0].user in self._resident_users():
                # one slot per user at a time: a user's trajectory is a
                # single sequential record stream. Leave it queued; it
                # admits when the resident job frees its slot.
                return
            job = self.queue.popleft()
            params, opt, done = self.store.materialize_state(job.user)
            self._prior[slot] = list(self.store.records(job.user))
            seed = (derive_user_seed(self.seed, job.user)
                    if job.seed is None else int(job.seed))
            resident = {int(self._user_seed[i])
                        for i in range(self.n_slots)
                        if self._active[i]}
            if seed in resident:
                raise ValueError(
                    f"per-user seed collision admitting {job.user!r} "
                    f"(seed {seed}): set an explicit TrainJob.seed — two "
                    f"co-resident users sharing a base seed would draw "
                    f"identical z streams")
            self._state = install_user(
                self._state,
                TrainState(params=params, step=jnp.uint32(done), opt=opt),
                slot)
            self._job[slot] = job
            self._active[slot] = True
            self._user_seed[slot] = np.uint32(seed)
            self._step[slot] = self._start[slot] = done
            self._target[slot] = job.n_steps
            self._lr[slot] = float(self.mz.lr if job.lr is None else job.lr)
            self._eps[slot] = float(self.mz.eps if job.eps is None
                                    else job.eps)
            self._pending[slot] = []
            self.stats.admitted += 1
            if done >= job.n_steps:      # target already met by the log
                self._finish(slot)

    def _batch_at(self, job: TrainJob, step: int):
        b = (job.batches(step) if callable(job.batches)
             else job.batches[step])
        return {k: np.asarray(v) for k, v in b.items()}

    def _flush(self, slot: int) -> JobResult:
        """Host-sync the slot's pending rows into replay records, push
        the grown log to the store (and log_dir), build the result."""
        job = self._job[slot]
        lr, eps = float(self._lr[slot]), float(self._eps[slot])
        records, losses = list(self._prior[slot]), []
        for step, seed, gs, loss in self._pending[slot]:
            # exact ReplayLog.append key order/values: the engine's
            # records are line-identical to a sequential Trainer's log
            records.append({
                "step": int(step), "seed": int(seed),
                "gs": np.asarray(gs, np.float32).reshape(-1).tolist(),
                "lr": lr, "eps": eps})
            losses.append(float(np.asarray(loss)))
        self._pending[slot] = []
        if records:
            self.store.put(job.user, records)
        if self.log_dir and losses:
            # append only this residency's new records: the file opens
            # in append mode, so across evict/re-admit cycles it
            # accumulates the user's full stream and AdapterStore.load
            # reconstructs the whole trajectory after a crash
            log = ReplayLog(os.path.join(self.log_dir,
                                         f"{job.user}.jsonl"))
            for rec in records[len(self._prior[slot]):]:
                log.append(rec["step"], rec["seed"], rec["gs"],
                           rec["lr"], rec["eps"])
            log.close()
        return JobResult(user=job.user, jid=job.jid,
                         start_step=int(self._start[slot]),
                         n_steps=int(self._step[slot]), losses=losses,
                         records=records)

    def _release(self, slot: int):
        self._job[slot] = None
        self._active[slot] = False
        self._prior[slot] = []

    def _finish(self, slot: int):
        res = self._flush(slot)
        self._results.append(res)
        self._release(slot)
        self.stats.finished += 1

    def evict(self, user: str) -> JobResult:
        """Flush a mid-flight user's records and free its slot. The
        returned result has ``evicted=True``; resubmitting a job for the
        user resumes from the flushed log, bit-identical to having never
        been evicted."""
        for slot in range(self.n_slots):
            if self._active[slot] and self._job[slot].user == user:
                res = self._flush(slot)
                res.evicted = True
                self._results.append(res)
                self._release(slot)
                self.stats.evicted += 1
                return res
        raise KeyError(f"user {user!r} is not resident")

    # ---- the batched step ------------------------------------------------
    def step(self) -> bool:
        """Admit whatever fits, then advance every active slot one user
        step in ONE batched dispatch. Returns False when idle."""
        self._admit()
        if not self._active.any():
            return False
        t0 = time.perf_counter()
        lane_batch = {}
        for slot in np.flatnonzero(self._active):
            b = self._batch_at(self._job[slot], int(self._step[slot]))
            if self._template_batch is None:
                self._template_batch = {
                    k: np.zeros_like(v) for k, v in b.items()}
            lane_batch[int(slot)] = b
        lanes = [lane_batch.get(slot, self._template_batch)
                 for slot in range(self.n_slots)]
        batch = jax.tree.map(
            lambda *xs: jnp.asarray(np.stack(xs)), *lanes)
        seeds = np.asarray(zrng.fold_seed(
            self._user_seed, self._step.astype(np.uint32)), np.uint32)
        self._state, aux = self.strategy.step_users(
            self.model.loss, self._state, batch, jnp.asarray(seeds),
            self.mz, self._active.copy(),
            eps=jnp.asarray(self._eps, jnp.float32),
            lr=jnp.asarray(self._lr, jnp.float32))
        for slot in np.flatnonzero(self._active):
            self._pending[slot].append(
                (int(self._step[slot]), int(seeds[slot]),
                 aux.gs[slot], aux.loss[slot]))
            self._step[slot] += 1
        self.stats.dispatches += 1
        self.stats.user_steps += int(self._active.sum())
        for slot in np.flatnonzero(self._active):
            if self._step[slot] >= self._target[slot]:
                self._finish(slot)
        self.stats.train_s += time.perf_counter() - t0
        return True

    def drain_results(self) -> List[JobResult]:
        out, self._results = self._results, []
        return out

    def run(self) -> List[JobResult]:
        """Train until queue and slots are empty; results jid-sorted."""
        out: List[JobResult] = []
        while self.queue or self._active.any():
            self.step()
            out.extend(self.drain_results())
        return sorted(out, key=lambda r: r.jid)
